//! Multi-tenant serving: several models sharing one chip's tiles, with
//! per-tenant latency accounting and saturation-knee detection.

use crate::scenario::ModelId;
use crate::telemetry::LogHistogram;

/// One serving tenant: a named model whose requests share the chip with
/// every other tenant's.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tenant {
    /// Display name (the model's canonical string, suffixed on
    /// collision).
    pub name: String,
    pub model: ModelId,
}

/// The set of models co-resident on one chip. All tenants share one
/// arrival spec; per-tenant salts decorrelate their streams.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantMix {
    pub tenants: Vec<Tenant>,
}

impl TenantMix {
    /// A mix over the given models, named by their canonical strings
    /// (`#k`-suffixed when one model serves several tenants).
    pub fn new(models: Vec<ModelId>) -> Self {
        let mut tenants: Vec<Tenant> = Vec::with_capacity(models.len());
        for model in models {
            let base = model.to_string();
            let dup = tenants.iter().filter(|t| t.model == model).count();
            let name = if dup == 0 { base } else { format!("{base}#{}", dup + 1) };
            tenants.push(Tenant { name, model });
        }
        TenantMix { tenants }
    }

    /// The single-tenant mix — what `simulate --serve` runs for the
    /// scenario's model.
    pub fn single(model: ModelId) -> Self {
        TenantMix::new(vec![model])
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }
}

/// Per-tenant serving outcome: request conservation counters plus the
/// three latency views (end-to-end = queueing + network).
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub name: String,
    /// Requests the arrival process generated.
    pub offered: u64,
    /// Requests the batcher put on the network.
    pub dispatched: u64,
    /// Requests whose batch fully drained.
    pub delivered: u64,
    /// Requests dispatched but not drained (horizon-cut runs only).
    pub in_flight: u64,
    /// Requests never dispatched (`offered - dispatched`).
    pub queued: u64,
    /// Batches the policy dispatched.
    pub batches: u64,
    /// End-to-end latency: arrival to batch drain, cycles.
    pub e2e: LogHistogram,
    /// Queueing delay: arrival to batch dispatch (bounded by the batch
    /// timeout).
    pub queue: LogHistogram,
    /// Network latency: batch dispatch to batch drain.
    pub net: LogHistogram,
}

impl TenantStats {
    pub fn new(name: String) -> Self {
        TenantStats {
            name,
            offered: 0,
            dispatched: 0,
            delivered: 0,
            in_flight: 0,
            queued: 0,
            batches: 0,
            e2e: LogHistogram::new(),
            queue: LogHistogram::new(),
            net: LogHistogram::new(),
        }
    }

    /// Delivered throughput in requests per megacycle — directly
    /// comparable to the spec's offered `rate_pmc`.
    pub fn delivered_rate_pmc(&self, makespan: u64) -> f64 {
        self.delivered as f64 * 1e6 / makespan.max(1) as f64
    }
}

/// First load step whose p99 exceeds `k` times the unloaded (step 0)
/// p99 — the saturation knee. `None` when the series never crosses
/// (or has fewer than two steps).
pub fn detect_knee(p99: &[u64], k: f64) -> Option<usize> {
    let base = (*p99.first()?).max(1) as f64;
    p99.iter().enumerate().skip(1).find(|(_, &v)| v as f64 > k * base).map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_names_disambiguate_duplicates() {
        let mix = TenantMix::new(vec![ModelId::LeNet, ModelId::CdbNet, ModelId::LeNet]);
        let names: Vec<&str> = mix.tenants.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["lenet", "cdbnet", "lenet#2"]);
        assert_eq!(TenantMix::single(ModelId::LeNet).len(), 1);
    }

    #[test]
    fn knee_is_the_first_crossing() {
        assert_eq!(detect_knee(&[100, 150, 300, 500, 900], 4.0), Some(3));
        assert_eq!(detect_knee(&[100, 401], 4.0), Some(1));
        assert_eq!(detect_knee(&[100, 120, 130], 4.0), None, "flat series has no knee");
        assert_eq!(detect_knee(&[], 4.0), None);
        assert_eq!(detect_knee(&[100], 4.0), None);
        // a zero baseline clamps to 1 instead of making every step a knee
        assert_eq!(detect_knee(&[0, 3, 5], 4.0), Some(2));
    }

    #[test]
    fn delivered_rate_is_in_requests_per_megacycle() {
        let mut st = TenantStats::new("t".into());
        st.delivered = 50;
        assert_eq!(st.delivered_rate_pmc(1_000_000), 50.0);
        assert!(st.delivered_rate_pmc(0).is_finite(), "zero makespan is guarded");
    }
}

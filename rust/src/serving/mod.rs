//! Open-loop inference serving: arrival processes, continuous batching,
//! and multi-tenant tail-latency accounting.
//!
//! Every other scenario in the crate is a *closed-loop* training
//! iteration: inject one iteration's traffic, measure its mean latency.
//! This module is ROADMAP item 2's "millions of users" story — an
//! *open-loop* workload where requests arrive on their own clock
//! whether or not the NoC has drained the previous ones, so the figure
//! of merit becomes tail latency under contention:
//!
//! * [`ArrivalProcess`] — Poisson / bursty / trace-driven request
//!   arrivals, generated as deterministic seeded cycle stamps (see the
//!   [`GRAMMAR`]). Determinism mirrors the fault plan: streams derive
//!   only from (spec, seed, tenant salt), never from thread or
//!   workspace state.
//! * [`BatchPolicy`] — continuous batching: a batch dispatches when `B`
//!   requests are waiting or `T` cycles after the oldest arrived,
//!   whichever first. The timeout bounds queueing delay at light load,
//!   which is what makes the saturation knee detectable.
//! * [`TenantMix`] — several models sharing one chip's tiles, each with
//!   per-tenant [`crate::telemetry::LogHistogram`] end-to-end latency
//!   split into queueing delay and network latency, plus
//!   delivered-vs-offered throughput and [`detect_knee`].
//! * [`run_serving`] — lowers each dispatched batch to forward-only
//!   phase traffic and injects it open-loop through the gated
//!   calendar-queue simulator
//!   ([`crate::noc::sim::NocSim::run_timeline_telemetry`]): the first
//!   phase of a batch has no predecessors, so its absolute `inject_at`
//!   offsets *are* the dispatch cycle; later phases gate on their
//!   predecessor's drain exactly like schedule instances.
//!
//! A [`ServingSpec`] parses from the same compact clause grammar as
//! [`crate::faults::FaultPlan`], rides inside [`crate::ScenarioKey`]
//! (all-integer fields), and defaults to [`ServingSpec::none`] — the
//! entire subsystem is behind `is_none()` checks, so serving-off runs
//! stay byte-identical to the pre-serving code paths.

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;

pub mod arrival;
pub mod batcher;
pub mod run;
pub mod tenant;

pub use arrival::ArrivalProcess;
pub use batcher::{batches, Batch, BatchPolicy};
pub use run::{run_serving, run_serving_faults, run_serving_obs, ServingReport};
pub use tenant::{detect_knee, Tenant, TenantMix, TenantStats};

/// The `--serve` grammar (embedded in every parse error).
pub const GRAMMAR: &str = "serve grammar:
  <spec>    := none | <arrival>[;<load>]
  <arrival> := poisson:rate=<r>[,seed=<n>]            Poisson arrivals, <r> requests per kilocycle
             | burst:rate=<r>,on=<a>,off=<b>[,x=<m>]  on/off Poisson: rate*<m> inside each <a>-cycle on-window (x default 4)
             | trace:file=<path>                      one absolute arrival cycle per line ('#' comments)
  <load>    := [batch=<b>][,timeout=<t>][,n=<k>]      dispatch on <b> requests or <t> cycles (defaults 4/256); <k> requests per tenant (default 64)
  examples: poisson:rate=0.5 | burst:rate=0.25,on=4096,off=12288,x=8;batch=8,timeout=512 | trace:file=arrivals.txt;n=32";

/// Default continuous-batching batch size.
pub const DEFAULT_BATCH: u32 = 4;
/// Default continuous-batching timeout, cycles.
pub const DEFAULT_TIMEOUT: u64 = 256;
/// Default offered requests per tenant.
pub const DEFAULT_REQUESTS: u32 = 64;

pub(crate) fn parse_num<T: FromStr>(key: &str, v: &str) -> Result<T, WihetError> {
    v.trim().parse::<T>().map_err(|_| {
        WihetError::InvalidArg(format!("{key}={v} is not a valid number\n{GRAMMAR}"))
    })
}

/// A typed, deterministic serving spec. Parses from the [`GRAMMAR`];
/// rates are stored in integer requests-per-megacycle so the spec can
/// ride inside the `Hash + Eq` [`crate::ScenarioKey`] (same trick as
/// `FaultPlan::wire_rate_ppm`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ServingSpec {
    /// Request arrival process; `None` means serving is off and every
    /// code path behaves exactly as before this subsystem existed.
    pub arrival: Option<ArrivalProcess>,
    /// Continuous-batching batch size: dispatch when this many requests
    /// are waiting.
    pub batch: u32,
    /// Continuous-batching timeout: dispatch `timeout` cycles after the
    /// oldest waiting request arrived, even if the batch is not full.
    pub timeout: u64,
    /// Offered requests per tenant.
    pub requests: u32,
}

impl Default for ServingSpec {
    fn default() -> Self {
        ServingSpec {
            arrival: None,
            batch: DEFAULT_BATCH,
            timeout: DEFAULT_TIMEOUT,
            requests: DEFAULT_REQUESTS,
        }
    }
}

impl ServingSpec {
    /// The empty spec: serving off, byte-identical to pre-serving runs.
    pub fn none() -> Self {
        ServingSpec::default()
    }

    /// True when serving is off.
    pub fn is_none(&self) -> bool {
        self.arrival.is_none()
    }

    /// The continuous-batching policy of this spec.
    pub fn policy(&self) -> BatchPolicy {
        BatchPolicy { batch: self.batch, timeout: self.timeout }
    }

    /// Semantic checks beyond the grammar. The empty spec is always
    /// valid.
    pub fn validate(&self) -> Result<(), WihetError> {
        let Some(a) = &self.arrival else { return Ok(()) };
        a.validate()?;
        if self.batch == 0 {
            return Err(WihetError::InvalidArg(format!(
                "serve: batch must be >= 1\n{GRAMMAR}"
            )));
        }
        if self.timeout == 0 {
            return Err(WihetError::InvalidArg(format!(
                "serve: timeout must be >= 1 cycle\n{GRAMMAR}"
            )));
        }
        if self.requests == 0 {
            return Err(WihetError::InvalidArg(format!(
                "serve: n must be >= 1 request\n{GRAMMAR}"
            )));
        }
        Ok(())
    }
}

impl fmt::Display for ServingSpec {
    /// Canonical form (defaults omitted); round-trips through
    /// [`ServingSpec::from_str`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let Some(a) = &self.arrival else { return f.pad("none") };
        let mut parts = vec![a.to_string()];
        let mut kv: Vec<String> = Vec::new();
        if self.batch != DEFAULT_BATCH {
            kv.push(format!("batch={}", self.batch));
        }
        if self.timeout != DEFAULT_TIMEOUT {
            kv.push(format!("timeout={}", self.timeout));
        }
        if self.requests != DEFAULT_REQUESTS {
            kv.push(format!("n={}", self.requests));
        }
        if !kv.is_empty() {
            parts.push(kv.join(","));
        }
        f.pad(&parts.join(";"))
    }
}

impl FromStr for ServingSpec {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        let t = s.trim();
        let mut spec = ServingSpec::none();
        if t.is_empty() || t.eq_ignore_ascii_case("none") {
            return Ok(spec);
        }
        for clause in t.split(';') {
            let clause = clause.trim();
            if clause.contains(':') {
                // headed clause: an arrival process
                if spec.arrival.is_some() {
                    return Err(WihetError::InvalidArg(format!(
                        "at most one arrival clause per serve spec\n{GRAMMAR}"
                    )));
                }
                spec.arrival = Some(clause.parse()?);
            } else {
                // headless load clause: batch=<b>,timeout=<t>,n=<k>
                for item in clause.split(',') {
                    let (k, v) = item.split_once('=').ok_or_else(|| {
                        WihetError::InvalidArg(format!(
                            "expected key=value in serve load clause, got '{item}'\n{GRAMMAR}"
                        ))
                    })?;
                    match k.trim() {
                        "batch" => spec.batch = parse_num("batch", v)?,
                        "timeout" => spec.timeout = parse_num("timeout", v)?,
                        "n" => spec.requests = parse_num("n", v)?,
                        other => {
                            return Err(WihetError::InvalidArg(format!(
                                "unknown key '{other}' in serve load clause\n{GRAMMAR}"
                            )));
                        }
                    }
                }
            }
        }
        if spec.arrival.is_none() {
            return Err(WihetError::InvalidArg(format!(
                "serve spec '{t}' has no arrival clause (poisson:/burst:/trace:)\n{GRAMMAR}"
            )));
        }
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_none_and_displays() {
        let spec = ServingSpec::none();
        assert!(spec.is_none());
        assert_eq!(spec.to_string(), "none");
        assert_eq!("none".parse::<ServingSpec>().unwrap(), spec);
        assert_eq!("".parse::<ServingSpec>().unwrap(), spec);
        spec.validate().unwrap();
    }

    #[test]
    fn parse_fills_defaults() {
        let spec: ServingSpec = "poisson:rate=0.5".parse().unwrap();
        assert!(!spec.is_none());
        assert_eq!(spec.batch, DEFAULT_BATCH);
        assert_eq!(spec.timeout, DEFAULT_TIMEOUT);
        assert_eq!(spec.requests, DEFAULT_REQUESTS);
        assert_eq!(
            spec.arrival,
            Some(ArrivalProcess::Poisson { rate_pmc: 500, seed: 0 })
        );
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for s in [
            "poisson:rate=0.5",
            "poisson:rate=2,seed=7",
            "burst:rate=0.25,on=4096,off=12288",
            "burst:rate=0.25,on=4096,off=12288,x=8;batch=8,timeout=512",
            "trace:file=arrivals.txt;n=32",
            "poisson:rate=0.5;batch=1,timeout=1,n=1",
            "none",
        ] {
            let spec: ServingSpec = s.parse().unwrap();
            let canon = spec.to_string();
            let again: ServingSpec = canon.parse().unwrap();
            assert_eq!(spec, again, "{s} -> {canon}");
        }
    }

    #[test]
    fn load_clause_alone_needs_an_arrival() {
        let err = "batch=8,timeout=512".parse::<ServingSpec>().unwrap_err();
        let WihetError::InvalidArg(msg) = err else { panic!("wrong variant") };
        assert!(msg.contains("no arrival clause"), "{msg}");
        assert!(msg.contains("serve grammar"), "{msg}");
    }

    #[test]
    fn errors_carry_the_grammar() {
        for bad in [
            "poisson:rate=zero",
            "poisson:speed=1",
            "poisson:rate=0",
            "poisson:rate=-1",
            "burst:rate=0.5",
            "burst:rate=0.5,on=0,off=64",
            "trace:",
            "trace:path=x",
            "arrivals:rate=1",
            "poisson:rate=1;poisson:rate=2",
            "poisson:rate=1;batch=0",
            "poisson:rate=1;batch",
            "poisson:rate=1;pace=3",
        ] {
            let err = bad.parse::<ServingSpec>().unwrap_err();
            let WihetError::InvalidArg(msg) = err else {
                panic!("{bad}: wrong error variant");
            };
            assert!(msg.contains("serve grammar"), "{bad}: {msg}");
        }
    }

    #[test]
    fn specs_hash_into_keys() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert("poisson:rate=0.5".parse::<ServingSpec>().unwrap());
        set.insert("poisson:rate=0.5;batch=8".parse::<ServingSpec>().unwrap());
        set.insert(ServingSpec::none());
        assert_eq!(set.len(), 3);
        assert!(set.contains(&"poisson:rate=0.5".parse::<ServingSpec>().unwrap()));
    }
}

//! `wihetnoc` — CLI for the WiHetNoC reproduction.
//!
//! Subcommands:
//!   experiment <id|all>     regenerate a paper table/figure (table1, fig5..fig19)
//!                           as text, JSON, or CSV (--format), optionally writing
//!                           <id>.{json,csv,txt} + attachments under --out DIR
//!   train                   train a CNN through the PJRT artifacts (L3 path)
//!   design                  run the NoC design flow on any platform and print the result
//!   simulate                simulate one training iteration on a chosen NoC/platform
//!   list                    list experiments and manifest entries
//!
//! Platforms are typed: `--system 8x8` (the paper chip), `--system 4x4`,
//! `--system 12x12:cpus=8,mcs=8,placement=corners`, ... Unknown models,
//! NoCs, experiments, and malformed platforms are reported as errors —
//! never panics.

use std::process::ExitCode;

use wihetnoc::coordinator::{TrainConfig, Trainer};
use wihetnoc::experiments::{self, ArtifactSink, Ctx, Effort};
use wihetnoc::noc::analysis::analyze;
use wihetnoc::noc::builder::{NocDesigner, NocKind};
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::runtime::Runtime;
use wihetnoc::traffic::trace::training_trace;
use wihetnoc::util::cli::{parse, usage, ArgSpec, Args};
use wihetnoc::fabric::run_fabric_obs;
use wihetnoc::schedule::run_schedule_obs;
use wihetnoc::serving::{run_serving_obs, TenantMix};
use wihetnoc::telemetry::{chrome_trace, class_line, search_sink, sink_trace, ClassPercentiles, Telemetry};
use wihetnoc::workload::preset_names;
use wihetnoc::{
    Fabric, FaultPlan, MappingPolicy, ModelId, Platform, Scenario, SchedulePolicy, ServingSpec,
    WihetError,
};

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{}", top_usage());
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "experiment" => cmd_experiment(rest),
        "train" => cmd_train(rest),
        "design" => cmd_design(rest),
        "simulate" => cmd_simulate(rest),
        "list" => cmd_list(rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", top_usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn top_usage() -> String {
    "wihetnoc — WiHetNoC reproduction (Choi et al., IEEE TC 2017)\n\
     usage: wihetnoc <experiment|train|design|simulate|list> [options]\n\
     platforms are typed: --system 8x8 | 4x4 | 12x12:cpus=8,mcs=8,placement=corners\n\
     run `wihetnoc <command> --help` for command options"
        .to_string()
}

fn common_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec { name: "seed", help: "PRNG seed", default: Some("42"), is_flag: false },
        ArgSpec {
            name: "effort",
            help: "quick|full (AMOSA budget + trace scale)",
            default: Some("quick"),
            is_flag: false,
        },
    ]
}

const SYSTEM_HELP: &str = "platform: WxH[:cpus=N,mcs=N,placement=centered|corners]";

fn system_spec() -> ArgSpec {
    ArgSpec { name: "system", help: SYSTEM_HELP, default: Some("8x8"), is_flag: false }
}

fn model_spec() -> ArgSpec {
    ArgSpec {
        name: "model",
        help: "lenet|cdbnet|alexnet|vgg11|resnet-lite, or a workload-DSL spec \
               (e.g. \"conv:5x5x20 pool:2 dense:10\")",
        default: Some("lenet"),
        is_flag: false,
    }
}

fn mapping_spec() -> ArgSpec {
    ArgSpec {
        name: "mapping",
        help: "data[:replicas]|pipeline[:stages] — how layers map onto tiles",
        default: Some("data:1"),
        is_flag: false,
    }
}

fn schedule_spec() -> ArgSpec {
    ArgSpec {
        name: "schedule",
        help: "serial|gpipe:M|1f1b:M — microbatch overlap of the training timeline",
        default: Some("serial"),
        is_flag: false,
    }
}

fn fabric_spec() -> ArgSpec {
    ArgSpec {
        name: "fabric",
        help: "N[:alpha=T,beta=BW,topo=ring|tree|hierarchical|auto] — \
               data-parallel chips + inter-chip link (default: 1, single chip)",
        default: Some("1"),
        is_flag: false,
    }
}

fn faults_spec() -> ArgSpec {
    ArgSpec {
        name: "faults",
        help: "fault plan: wire:link=L[,at=T] | wire:rate=F[,seed=S] | \
               air:ch=C,from=T,burst=D | chip:n=K[,slow=Fx][,drop=R] — \
               ';'-separated clauses (default: none)",
        default: None,
        is_flag: false,
    }
}

fn serve_spec() -> ArgSpec {
    ArgSpec {
        name: "serve",
        help: "open-loop serving instead of a training iteration: \
               poisson:rate=R[,seed=S] | burst:rate=R,on=A,off=B[,x=M] | trace:file=PATH, \
               plus batch=B;timeout=T;n=N — ';'-separated clauses (default: off)",
        default: None,
        is_flag: false,
    }
}

fn str_err(e: WihetError) -> String {
    e.to_string()
}

/// Parse the common typed pieces into a `Scenario`.
fn scenario_from(args: &Args) -> Result<Scenario, String> {
    let platform: Platform = args.get_or("system", "8x8").parse().map_err(str_err)?;
    let model: ModelId = args.get_or("model", "lenet").parse().map_err(str_err)?;
    let mapping: MappingPolicy =
        args.get_or("mapping", "data:1").parse().map_err(str_err)?;
    let schedule: SchedulePolicy =
        args.get_or("schedule", "serial").parse().map_err(str_err)?;
    let fabric: Fabric = args.get_or("fabric", "1").parse().map_err(str_err)?;
    let faults: FaultPlan = match args.get("faults") {
        Some(s) => s.parse().map_err(str_err)?,
        None => FaultPlan::none(),
    };
    let serving: ServingSpec = match args.get("serve") {
        Some(s) => s.parse().map_err(str_err)?,
        None => ServingSpec::none(),
    };
    let effort: Effort = args.get_or("effort", "quick").parse().map_err(str_err)?;
    let seed = args.get_u64("seed", 42)?;
    Ok(Scenario::new(platform, model)
        .with_mapping(mapping)
        .with_schedule(schedule)
        .with_fabric(fabric)
        .with_faults(faults)
        .with_serving(serving)
        .with_effort(effort)
        .with_seed(seed))
}

fn ctx_from(args: &Args) -> Result<Ctx, String> {
    let seed = args.get_u64("seed", 42)?;
    let effort: Effort = args.get_or("effort", "quick").parse().map_err(str_err)?;
    Ok(Ctx::new(effort, seed))
}

fn cmd_experiment(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.extend([
        ArgSpec {
            name: "format",
            help: "text|json|csv — how reports render on stdout",
            default: Some("text"),
            is_flag: false,
        },
        ArgSpec {
            name: "out",
            help: "directory for <id>.{json,csv,txt} + attachments",
            default: None,
            is_flag: false,
        },
    ]);
    let args = parse(argv, &specs)?;
    let Some(id) = args.positional.first() else {
        return Err(format!(
            "usage: wihetnoc experiment <id|all> [--effort quick|full] [--format text|json|csv] [--out DIR]\nids: {}\n{}",
            experiments::ALL.join(", "),
            usage(&specs)
        ));
    };
    let format = args.get_or("format", "text");
    if !matches!(format.as_str(), "text" | "json" | "csv") {
        return Err(format!("--format must be text|json|csv, got '{format}'"));
    }
    let sink = match args.get("out") {
        Some(dir) => Some(ArtifactSink::new(dir).map_err(str_err)?),
        None => None,
    };
    let mut ctx = ctx_from(&args)?;
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let report = experiments::run(id, &mut ctx).map_err(str_err)?;
        match format.as_str() {
            "json" => println!("{}", report.to_json().dump()),
            "csv" => print!("{}", report.to_csv()),
            _ => {
                println!("{}", report.to_text());
                println!("[{id} done in {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
        }
        if let Some(sink) = &sink {
            let paths = sink.write(&report).map_err(str_err)?;
            eprintln!("[{id}: wrote {} files under {}]", paths.len(), sink.dir().display());
        }
    }
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.extend([
        model_spec(),
        ArgSpec { name: "steps", help: "training steps", default: Some("100"), is_flag: false },
        ArgSpec {
            name: "artifacts",
            help: "artifacts directory",
            default: Some("artifacts"),
            is_flag: false,
        },
    ]);
    let args = parse(argv, &specs)?;
    let model: ModelId = args.get_or("model", "lenet").parse().map_err(str_err)?;
    let steps = args.get_usize("steps", 100)?;
    let seed = args.get_u64("seed", 42)?;
    let mut rt = Runtime::new(args.get_or("artifacts", "artifacts")).map_err(|e| format!("{e:#}"))?;
    let batch = rt.manifest.batch;
    println!("platform: {} | model: {model} | batch: {batch} | steps: {steps}", rt.platform());
    let spec = model.spec();
    let mut trainer = Trainer::new(&mut rt, spec, seed).map_err(|e| format!("{e:#}"))?;
    let cfg = TrainConfig { steps, batch, seed, log_every: (steps / 20).max(1) };
    let log = trainer.train(&cfg).map_err(|e| format!("{e:#}"))?;
    for (step, loss) in &log.losses {
        println!("step {step:>5}  loss {loss:.4}");
    }
    println!(
        "loss {:.4} -> {:.4} | {:.2}s total, {:.1} ms/step (PJRT {:.1} ms/step)",
        log.first_loss(),
        log.last_loss(),
        log.total_secs,
        1e3 * log.total_secs / steps as f64,
        1e3 * log.execute_secs / steps as f64,
    );
    Ok(())
}

fn cmd_design(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.extend([
        system_spec(),
        model_spec(),
        mapping_spec(),
        ArgSpec {
            name: "noc",
            help: "mesh_xy|mesh_opt|hetnoc|wihetnoc",
            default: Some("wihetnoc"),
            is_flag: false,
        },
        ArgSpec { name: "kmax", help: "router port bound (default: scaled)", default: None, is_flag: false },
        ArgSpec { name: "nwi", help: "GPU-MC wireless interfaces (default: scaled)", default: None, is_flag: false },
        ArgSpec { name: "channels", help: "GPU-MC channels (default: scaled)", default: None, is_flag: false },
        ArgSpec {
            name: "search-trace",
            help: "write the AMOSA convergence trace JSON to this path",
            default: None,
            is_flag: false,
        },
        ArgSpec {
            name: "profile",
            help: "print the design-search eval-attribution table",
            default: None,
            is_flag: true,
        },
    ]);
    let args = parse(argv, &specs)?;
    let noc: NocKind = args.get_or("noc", "wihetnoc").parse().map_err(str_err)?;
    let search_path = args.get("search-trace").map(|s| s.to_string());
    let want_profile = args.has_flag("profile");
    let sink = (search_path.is_some() || want_profile).then(search_sink);
    let scenario = scenario_from(&args)?.with_noc(noc);
    let mut designer = NocDesigner::for_scenario(&scenario).map_err(str_err)?;
    if let Some(sink) = &sink {
        designer = designer.observe(sink.clone());
    }
    if args.get("kmax").is_some() {
        designer = designer.k_max(args.get_usize("kmax", 0)?);
    }
    if args.get("nwi").is_some() {
        designer = designer.n_wi(args.get_usize("nwi", 0)?);
    }
    if args.get("channels").is_some() {
        designer = designer.gpu_channels(args.get_usize("channels", 0)?);
    }
    let sys = designer.system().clone();
    let cfg = designer.config().clone();
    let fij = designer
        .traffic_matrix()
        .expect("for_scenario always derives traffic")
        .clone();
    println!(
        "designing {} on {} ({} GPU / {} CPU / {} MC, workload {}): k_max={} n_wi={} channels={}+1 ...",
        scenario.noc,
        scenario.platform,
        sys.gpus().len(),
        sys.cpus().len(),
        sys.mcs().len(),
        scenario.model,
        cfg.k_max,
        cfg.n_wi,
        cfg.gpu_channels
    );
    let t0 = std::time::Instant::now();
    let inst = designer.build().map_err(str_err)?;
    let a = analyze(&inst.topo, &fij);
    println!(
        "done in {:.1}s: {} links (k_max {} k_avg {:.2}), {} WIs, {} virtual layers",
        t0.elapsed().as_secs_f64(),
        inst.topo.links.len(),
        inst.topo.k_max(),
        inst.topo.k_avg(),
        inst.air.wis.len(),
        inst.routes.num_layers,
    );
    println!(
        "objectives: U_mean={:.4} sigma={:.4} twhc={:.2} | air coverage {:.1}% | WI area {:.2} mm^2",
        a.u_mean,
        a.u_std,
        a.twhc,
        100.0 * inst.routes.air_coverage(),
        inst.air.total_area_mm2(),
    );
    if !inst.air.wis.is_empty() {
        println!("\nWI placement (router, channel):");
        for wi in &inst.air.wis {
            print!(" ({},{})", wi.router, wi.channel);
        }
        println!();
    }
    if let Some(sink) = &sink {
        let trace = sink_trace(sink);
        if want_profile {
            print!("\n{}", trace.profile_text());
        }
        if let Some(path) = &search_path {
            if let Some(parent) = std::path::Path::new(path).parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)
                        .map_err(|e| format!("creating {}: {e}", parent.display()))?;
                }
            }
            let mut text = trace.to_json().dump();
            text.push('\n');
            std::fs::write(path, text).map_err(|e| format!("writing {path}: {e}"))?;
            eprintln!(
                "[search trace: {} stages, {} evals -> {path}]",
                trace.stages().len(),
                trace.total_evals(),
            );
        }
    }
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.extend([
        system_spec(),
        model_spec(),
        mapping_spec(),
        schedule_spec(),
        fabric_spec(),
        faults_spec(),
        serve_spec(),
        ArgSpec {
            name: "noc",
            help: "mesh_xy|mesh_opt|hetnoc|wihetnoc",
            default: Some("wihetnoc"),
            is_flag: false,
        },
        ArgSpec { name: "scale", help: "trace downsampling", default: Some("0.05"), is_flag: false },
        ArgSpec {
            name: "trace",
            help: "write a Chrome-trace/Perfetto timeline JSON to this path",
            default: None,
            is_flag: false,
        },
        ArgSpec {
            name: "metrics",
            help: "print telemetry (latency percentiles, link hotspots, queue peaks)",
            default: None,
            is_flag: true,
        },
    ]);
    let args = parse(argv, &specs)?;
    let noc: NocKind = args.get_or("noc", "wihetnoc").parse().map_err(str_err)?;
    let trace_path = args.get("trace").map(|s| s.to_string());
    let want_metrics = args.has_flag("metrics");
    let mut tel =
        (trace_path.is_some() || want_metrics).then(Telemetry::new);
    let scenario = scenario_from(&args)?.with_noc(noc);
    let mut ctx = Ctx::for_scenario(&scenario).map_err(str_err)?;
    let inst = ctx.instance_arc(noc);
    let sys = ctx.sys_for(noc);
    let tm = ctx.traffic_on(scenario.model.clone(), &sys);
    let mut cfg = ctx.trace_cfg();
    cfg.scale = args.get_f64("scale", 0.05)?;
    let faults_tag = if scenario.faults.is_none() {
        String::new()
    } else {
        format!(", faults {}", scenario.faults)
    };
    if !scenario.serving.is_none() {
        // open-loop serving: the requested model becomes a single tenant
        // and inference batches arrive on the spec's clock instead of a
        // training iteration (Ctx::for_scenario already rejected fabric
        // and schedule combinations)
        let mix = TenantMix::single(scenario.model.clone());
        println!(
            "serving {noc} on {} ({}, serve {}{faults_tag}) ...",
            scenario.model, scenario.platform, scenario.serving
        );
        let t0 = std::time::Instant::now();
        let sr = run_serving_obs(
            &sys,
            &inst,
            &mix,
            &scenario.serving,
            &cfg,
            &scenario.faults,
            tel.as_mut(),
        )
        .map_err(str_err)?;
        println!(
            "{} packets in {:.2}s wall | {} offered -> {} dispatched in {} batches, {} delivered ({} in flight, {} queued) | makespan {} cyc | {:.3} req/Mcyc delivered",
            sr.sim.delivered_packets,
            t0.elapsed().as_secs_f64(),
            sr.offered,
            sr.dispatched,
            sr.batches,
            sr.delivered,
            sr.in_flight,
            sr.queued,
            sr.makespan,
            sr.delivered_rate_pmc(),
        );
        for t in &sr.tenants {
            println!(
                "tenant {}: {} delivered / {} offered ({} batches, {:.3} req/Mcyc)",
                t.name,
                t.delivered,
                t.offered,
                t.batches,
                t.delivered_rate_pmc(sr.makespan),
            );
            for (name, h) in [("e2e", &t.e2e), ("queue", &t.queue), ("net", &t.net)] {
                let line = class_line(name, &ClassPercentiles::of(h));
                if !line.is_empty() {
                    println!("{line}");
                }
            }
        }
        print_resilience(&scenario, sr.resilience(), sr.sim.undeliverable);
        emit_telemetry(tel.as_ref(), trace_path.as_deref(), want_metrics)?;
        return Ok(());
    }
    if !scenario.fabric.is_single() {
        // multi-chip fabric: co-simulate the chip's iteration with the
        // lowered allreduce and charge the alpha-beta inter-chip hops
        let grad = scenario.model.spec().total_weight_bytes();
        println!(
            "simulating {noc} on {} ({}, mapping {}, schedule {}, fabric {}{faults_tag}) ...",
            scenario.model, scenario.platform, scenario.mapping, scenario.schedule,
            scenario.fabric
        );
        let t0 = std::time::Instant::now();
        let fr = run_fabric_obs(
            &sys,
            &inst,
            &tm,
            &scenario.schedule,
            &scenario.fabric,
            grad,
            &cfg,
            &scenario.faults,
            tel.as_mut(),
        )
        .map_err(str_err)?;
        println!(
            "{} packets in {:.2}s wall | {} chips, {} allreduce ({} steps, {} B/chip on the wire) | makespan {} cyc, iteration {} cyc | comm overhead {:.1}% | bubble {:.1}%",
            fr.schedule.sim.delivered_packets,
            t0.elapsed().as_secs_f64(),
            fr.fabric.chips,
            fr.algorithm,
            fr.steps,
            fr.wire_bytes_per_chip,
            fr.schedule.makespan,
            fr.iteration_cycles,
            fr.comm_overhead_pct,
            100.0 * fr.schedule.bubble_fraction,
        );
        print_resilience(&scenario, &fr.resilience, fr.schedule.sim.undeliverable);
        emit_telemetry(tel.as_ref(), trace_path.as_deref(), want_metrics)?;
        return Ok(());
    }
    if !scenario.schedule.is_serial() {
        // overlapping schedule: expand the timeline and run the gated
        // concurrent simulation
        println!(
            "simulating {noc} on {} ({}, mapping {}, schedule {}{faults_tag}) ...",
            scenario.model, scenario.platform, scenario.mapping, scenario.schedule
        );
        let t0 = std::time::Instant::now();
        let sr = run_schedule_obs(
            &sys,
            &inst,
            &tm,
            &scenario.schedule,
            &cfg,
            &scenario.faults,
            tel.as_mut(),
        )
        .map_err(str_err)?;
        println!(
            "{} packets in {:.2}s wall | {} instances over {} stages | makespan {} cyc (speedup {:.2}x vs serial) | bubble {:.1}% | peak link concurrency {} | latency mean {:.2} | cpu-mc {:.2} | wireless {:.1}% (fallbacks {})",
            sr.sim.delivered_packets,
            t0.elapsed().as_secs_f64(),
            sr.instances,
            sr.num_stages,
            sr.makespan,
            sr.speedup_vs_serial,
            100.0 * sr.bubble_fraction,
            sr.peak_link_concurrency,
            sr.sim.latency.mean(),
            sr.sim.cpu_mc_latency.mean(),
            100.0 * sr.sim.wireless_utilization(),
            sr.sim.air_fallbacks,
        );
        print_resilience(&scenario, sr.resilience(), sr.sim.undeliverable);
        emit_telemetry(tel.as_ref(), trace_path.as_deref(), want_metrics)?;
        return Ok(());
    }
    let fx = if scenario.faults.has_noc_faults() {
        let nominal = SimConfig::default().nominal_flits;
        Some(
            scenario
                .faults
                .compile(&inst.topo, &inst.routes, &inst.air, nominal)
                .map_err(str_err)?,
        )
    } else {
        None
    };
    let (trace, windows) = training_trace(&sys, &tm.phases, &cfg);
    println!(
        "simulating {noc} on {} ({}, mapping {}{faults_tag}): {} messages ...",
        scenario.model,
        scenario.platform,
        scenario.mapping,
        trace.len()
    );
    let t0 = std::time::Instant::now();
    let mut sim =
        NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    if let Some(f) = &fx {
        sim = sim.with_faults(f);
    }
    let rep = sim.run_telemetry(&trace, tel.as_mut());
    if let Some(sink) = tel.as_mut() {
        for (p, &(start, end)) in tm.phases.iter().zip(&windows) {
            sink.span(p.tag.clone(), "phase", 0, start, end);
        }
    }
    println!(
        "{} packets in {:.2}s wall | latency mean {:.2} max {:.0} | cpu-mc {:.2} | throughput {:.3} flits/cyc | wireless {:.1}% (fallbacks {})",
        rep.delivered_packets,
        t0.elapsed().as_secs_f64(),
        rep.latency.mean(),
        rep.latency.max,
        rep.cpu_mc_latency.mean(),
        rep.throughput(),
        100.0 * rep.wireless_utilization(),
        rep.air_fallbacks,
    );
    print_resilience(&scenario, &rep.resilience, rep.undeliverable);
    emit_telemetry(tel.as_ref(), trace_path.as_deref(), want_metrics)?;
    Ok(())
}

/// Print `--metrics` and write `--trace` from a finished telemetry sink.
fn emit_telemetry(
    tel: Option<&Telemetry>,
    trace_path: Option<&str>,
    want_metrics: bool,
) -> Result<(), String> {
    let Some(tel) = tel else {
        return Ok(());
    };
    if want_metrics {
        print!("{}", tel.summary());
    }
    if let Some(path) = trace_path {
        let doc = chrome_trace(tel);
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("creating {}: {e}", parent.display()))?;
            }
        }
        let mut text = doc.dump();
        text.push('\n');
        std::fs::write(path, text).map_err(|e| format!("writing {path}: {e}"))?;
        eprintln!(
            "[trace: {} events -> {path}; open in chrome://tracing or https://ui.perfetto.dev]",
            tel.spans.len() + tel.instants.len(),
        );
    }
    Ok(())
}

/// One resilience line when a fault plan is active (silent otherwise).
fn print_resilience(
    scenario: &Scenario,
    rs: &wihetnoc::faults::ResilienceStats,
    undeliverable: u64,
) {
    if scenario.faults.is_none() {
        return;
    }
    println!(
        "resilience: {} faults injected | {} packets rerouted | {} retries | {} fallback flits | {} undeliverable after repair ({} undeliverable total)",
        rs.faults_injected,
        rs.packets_rerouted,
        rs.retries,
        rs.fallback_flits,
        rs.undeliverable_after_repair,
        undeliverable,
    );
}

fn cmd_list(argv: &[String]) -> Result<(), String> {
    let specs = vec![ArgSpec {
        name: "artifacts",
        help: "artifacts directory",
        default: Some("artifacts"),
        is_flag: false,
    }];
    let args = parse(argv, &specs)?;
    println!("experiments (run with `wihetnoc experiment <id|all> [--format text|json|csv] [--out DIR]`):");
    for e in experiments::REGISTRY {
        println!(
            "  {:<14} {}{}",
            e.id,
            e.title,
            if e.paper.is_empty() { String::new() } else { format!(" [{}]", e.paper) }
        );
    }
    println!(
        "models: {} — or any workload-DSL spec | mappings: data[:replicas], pipeline[:stages] | schedules: serial, gpipe:M, 1f1b:M | nocs: mesh_xy, mesh_opt, hetnoc, wihetnoc",
        preset_names().join(", ")
    );
    match Runtime::new(args.get_or("artifacts", "artifacts")) {
        Ok(rt) => {
            println!("artifact entries ({}):", rt.manifest.dir.display());
            for e in &rt.manifest.entries {
                println!(
                    "  {:<22} {} inputs, {} outputs ({})",
                    e.name,
                    e.inputs.len(),
                    e.num_outputs,
                    e.path
                );
            }
        }
        Err(e) => println!("artifacts not available: {e:#}"),
    }
    Ok(())
}

//! The training driver: owns parameters, streams batches through the AOT
//! train-step executable, and logs the loss curve. This is the "leader"
//! loop — pure Rust + PJRT, no Python.

use super::data::SyntheticDataset;
use crate::error::Result;
use crate::{wbail, werr};
use crate::model::cnn::ModelSpec;
use crate::runtime::Runtime;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub seed: u64,
    /// Log the loss every `log_every` steps (0 = only first/last).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 100, batch: 32, seed: 0x5EED, log_every: 10 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    /// (step, loss) samples.
    pub losses: Vec<(usize, f32)>,
    pub steps: usize,
    /// Wall seconds spent inside PJRT execute.
    pub execute_secs: f64,
    /// Wall seconds total (data gen + execute + bookkeeping).
    pub total_secs: f64,
}

impl TrainLog {
    pub fn first_loss(&self) -> f32 {
        self.losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }

    pub fn last_loss(&self) -> f32 {
        self.losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }

    /// Mean loss over the last k logged points (smooths SGD noise).
    pub fn tail_mean(&self, k: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return f32::NAN;
        }
        let tail = &self.losses[n.saturating_sub(k)..];
        tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32
    }
}

/// He-style initialization matching `python/compile/model.py::init_params`
/// in structure (exact values differ across PRNGs; scale is what matters).
pub fn init_params(spec: &ModelSpec, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let mut params = Vec::new();
    for l in &spec.layers {
        if !l.has_params() {
            continue;
        }
        let (fan_in, co) = match l.kind {
            crate::model::cnn::LayerKind::Conv => {
                (l.kernel * l.kernel * l.in_shape.2, l.out_shape.2)
            }
            _ => (l.in_shape.0 * l.in_shape.1 * l.in_shape.2, l.out_shape.2),
        };
        let w_len = fan_in * co;
        let scale = (2.0 / fan_in as f64).sqrt();
        params.push((0..w_len).map(|_| (rng.normal() * scale) as f32).collect());
        params.push(vec![0.0f32; co]);
    }
    params
}

/// Drives `<model>_train_step` from the artifacts.
pub struct Trainer<'r> {
    pub runtime: &'r mut Runtime,
    pub spec: ModelSpec,
    pub params: Vec<Vec<f32>>,
    entry_name: String,
}

impl<'r> Trainer<'r> {
    pub fn new(runtime: &'r mut Runtime, spec: ModelSpec, seed: u64) -> Result<Self> {
        let entry_name = format!("{}_train_step", spec.name);
        let entry = runtime.manifest.entry(&entry_name)?.clone();
        let params = init_params(&spec, seed);
        if entry.num_params != params.len() {
            wbail!(
                "manifest says {} params, model derives {}",
                entry.num_params,
                params.len()
            );
        }
        // validate shapes against the manifest signature
        for (i, p) in params.iter().enumerate() {
            let want = entry.inputs[i].elements();
            if p.len() != want {
                wbail!("param {i}: {} elements vs manifest {}", p.len(), want);
            }
        }
        runtime.load(&entry_name)?;
        Ok(Trainer { runtime, spec, params, entry_name })
    }

    /// One SGD step; returns the loss.
    pub fn step(&mut self, x: &[f32], y: &[f32]) -> Result<f32> {
        let mut args: Vec<Vec<f32>> = self.params.clone();
        args.push(x.to_vec());
        args.push(y.to_vec());
        let mut out = self.runtime.run(&self.entry_name, &args)?;
        let loss = out
            .pop()
            .ok_or_else(|| werr!("train_step returned nothing"))?
            .first()
            .copied()
            .ok_or_else(|| werr!("empty loss output"))?;
        if out.len() != self.params.len() {
            wbail!("expected {} updated params, got {}", self.params.len(), out.len());
        }
        self.params = out;
        Ok(loss)
    }

    /// Full training run on the synthetic dataset.
    pub fn train(&mut self, cfg: &TrainConfig) -> Result<TrainLog> {
        let t0 = std::time::Instant::now();
        let mut ds = SyntheticDataset::new(&self.spec, cfg.seed);
        let mut log = TrainLog::default();
        let mut exec = 0.0;
        for step in 0..cfg.steps {
            let (x, y) = ds.next_batch(cfg.batch);
            let te = std::time::Instant::now();
            let loss = self.step(&x, &y)?;
            exec += te.elapsed().as_secs_f64();
            if !loss.is_finite() {
                wbail!("loss diverged to {loss} at step {step}");
            }
            let should_log = step == 0
                || step + 1 == cfg.steps
                || (cfg.log_every > 0 && step % cfg.log_every == 0);
            if should_log {
                log.losses.push((step, loss));
            }
        }
        log.steps = cfg.steps;
        log.execute_secs = exec;
        log.total_secs = t0.elapsed().as_secs_f64();
        Ok(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{cdbnet, lenet};

    #[test]
    fn init_params_shapes() {
        let spec = lenet();
        let p = init_params(&spec, 1);
        // 4 weighted layers -> 8 tensors
        assert_eq!(p.len(), 8);
        assert_eq!(p[0].len(), 5 * 5 * 1 * 16);
        assert_eq!(p[1].len(), 16);
        assert_eq!(p[6].len(), 128 * 10);
        assert_eq!(p[7].len(), 10);
        // biases start at zero
        assert!(p[1].iter().all(|&v| v == 0.0));
        // weights have sane scale
        let rms = (p[0].iter().map(|&v| (v * v) as f64).sum::<f64>() / p[0].len() as f64).sqrt();
        assert!((0.1..0.6).contains(&rms), "rms {rms}");
    }

    #[test]
    fn init_matches_python_structure_cdbnet() {
        let p = init_params(&cdbnet(), 2);
        assert_eq!(p.len(), 8);
        assert_eq!(p[0].len(), 5 * 5 * 3 * 32);
        assert_eq!(p[6].len(), 64 * 10);
    }

    #[test]
    fn train_log_helpers() {
        let log = TrainLog {
            losses: vec![(0, 3.0), (10, 2.0), (20, 1.0)],
            steps: 21,
            execute_secs: 0.0,
            total_secs: 0.0,
        };
        assert_eq!(log.first_loss(), 3.0);
        assert_eq!(log.last_loss(), 1.0);
        assert_eq!(log.tail_mean(2), 1.5);
    }
}

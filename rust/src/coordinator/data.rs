//! Synthetic dataset generation, mirroring `python/compile/model.py`'s
//! `synthetic_batch`: class-conditional Gaussian images so training has
//! learnable structure (DESIGN.md §2 substitution for MNIST/CIFAR-10).
//!
//! The exact pixel values differ from the Python generator (different
//! PRNG); the learnability property — class means + noise — is identical,
//! which is what the loss-curve validation needs.

use crate::model::cnn::ModelSpec;
use crate::util::rng::Rng;

/// Deterministic synthetic image-classification dataset.
pub struct SyntheticDataset {
    pub input_shape: (usize, usize, usize),
    pub num_classes: usize,
    /// Per-class mean images, flattened.
    means: Vec<Vec<f32>>,
    noise: f32,
    rng: Rng,
}

impl SyntheticDataset {
    pub fn new(spec: &ModelSpec, seed: u64) -> Self {
        let (h, w, c) = spec.input_shape;
        let mut rng = Rng::new(seed);
        let means = (0..spec.num_classes)
            .map(|_| (0..h * w * c).map(|_| rng.normal() as f32).collect())
            .collect();
        SyntheticDataset {
            input_shape: spec.input_shape,
            num_classes: spec.num_classes,
            means,
            noise: 0.5,
            rng,
        }
    }

    /// Next batch: (images flattened NHWC, one-hot labels).
    pub fn next_batch(&mut self, batch: usize) -> (Vec<f32>, Vec<f32>) {
        let (h, w, c) = self.input_shape;
        let pix = h * w * c;
        let mut x = Vec::with_capacity(batch * pix);
        let mut y = vec![0.0f32; batch * self.num_classes];
        for b in 0..batch {
            let label = self.rng.below(self.num_classes);
            y[b * self.num_classes + label] = 1.0;
            let mean = &self.means[label];
            for p in 0..pix {
                x.push(mean[p] + self.noise * self.rng.normal() as f32);
            }
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lenet;

    #[test]
    fn batch_shapes() {
        let spec = lenet();
        let mut ds = SyntheticDataset::new(&spec, 1);
        let (x, y) = ds.next_batch(8);
        assert_eq!(x.len(), 8 * 33 * 33);
        assert_eq!(y.len(), 8 * 10);
        // one-hot rows
        for b in 0..8 {
            let row = &y[b * 10..(b + 1) * 10];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
            assert!(row.iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = lenet();
        let mut a = SyntheticDataset::new(&spec, 7);
        let mut b = SyntheticDataset::new(&spec, 7);
        assert_eq!(a.next_batch(4).0, b.next_batch(4).0);
        let mut c = SyntheticDataset::new(&spec, 8);
        assert_ne!(a.next_batch(4).0, c.next_batch(4).0);
    }

    #[test]
    fn classes_are_separated() {
        let spec = lenet();
        let ds = SyntheticDataset::new(&spec, 2);
        // distinct class means differ substantially
        let d: f32 = ds.means[0]
            .iter()
            .zip(&ds.means[1])
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / ds.means[0].len() as f32;
        assert!(d > 0.5, "mean L1 distance {d}");
    }
}

//! Training + NoC co-simulation: run real training steps through PJRT
//! while the traffic model + simulator evaluate the candidate NoCs on the
//! same workload — the Fig 19 end-to-end loop.

use crate::energy::params::EnergyParams;
use crate::energy::system::{
    full_system_run_fabric, full_system_run_scheduled, FullSystemReport, StallModel,
};
use crate::error::Result;
use crate::fabric::Fabric;
use crate::model::SystemConfig;
use crate::noc::builder::NocInstance;
use crate::schedule::SchedulePolicy;
use crate::traffic::phases::TrafficModel;
use crate::traffic::trace::TraceConfig;

#[derive(Debug, Clone)]
pub struct CosimReport {
    /// One full-system report per evaluated NoC, same order as input.
    pub per_noc: Vec<FullSystemReport>,
}

impl CosimReport {
    /// Execution time of NoC `i` normalized to NoC 0 (the mesh baseline).
    pub fn exec_vs_baseline(&self, i: usize) -> f64 {
        self.per_noc[i].exec_seconds / self.per_noc[0].exec_seconds
    }

    /// Full-system EDP of NoC `i` normalized to NoC 0.
    pub fn edp_vs_baseline(&self, i: usize) -> f64 {
        self.per_noc[i].edp / self.per_noc[0].edp
    }
}

/// Evaluate `nocs` under one training iteration of the lowered workload
/// `tm` (produced by `crate::traffic::model_phases` or, for mapped /
/// skip-connected workloads, `crate::workload::lower`). Taking the
/// traffic model — not a `ModelSpec` — keeps co-simulation on the same
/// lowering pipeline as every other consumer.
///
/// Each NoC's full-system run regenerates its traces from the same seed,
/// so the runs are independent and fan out over
/// [`crate::util::exec::par_map`] workers; results keep input order.
pub fn cosimulate(
    sys: &SystemConfig,
    tm: &TrafficModel,
    nocs: &[&NocInstance],
    trace_cfg: &TraceConfig,
) -> Result<CosimReport> {
    cosimulate_scheduled(sys, tm, &SchedulePolicy::Serial, nocs, trace_cfg)
}

/// [`cosimulate`] under a training-timeline schedule: `serial` is the
/// legacy per-phase loop; `gpipe:M`/`1f1b:M` run each NoC's whole
/// iteration as one gated concurrent simulation (see
/// [`crate::schedule::run_schedule`]). NoCs still fan out over
/// [`crate::util::exec::par_map`] workers with input-order results.
pub fn cosimulate_scheduled(
    sys: &SystemConfig,
    tm: &TrafficModel,
    schedule: &SchedulePolicy,
    nocs: &[&NocInstance],
    trace_cfg: &TraceConfig,
) -> Result<CosimReport> {
    let energy = EnergyParams::default();
    let stall = StallModel::default();
    let per_noc: Vec<_> = crate::util::exec::par_map(nocs, |_, inst| {
        full_system_run_scheduled(sys, inst, tm, schedule, trace_cfg, &energy, &stall)
    })
    .into_iter()
    .collect::<Result<_>>()?;
    Ok(CosimReport { per_noc })
}

/// [`cosimulate_scheduled`] on a multi-chip [`Fabric`]: each NoC's
/// per-chip iteration is co-simulated with the allreduce's on-chip
/// traffic and charged the alpha-beta inter-chip time and SerDes energy
/// (see [`crate::energy::full_system_run_fabric`]). `grad_bytes` is the
/// model's total weight bytes (`ModelId::spec().total_weight_bytes()`).
/// The single-chip fabric is byte-identical to [`cosimulate_scheduled`].
pub fn cosimulate_fabric(
    sys: &SystemConfig,
    tm: &TrafficModel,
    schedule: &SchedulePolicy,
    fabric: &Fabric,
    grad_bytes: u64,
    nocs: &[&NocInstance],
    trace_cfg: &TraceConfig,
) -> Result<CosimReport> {
    let energy = EnergyParams::default();
    let stall = StallModel::default();
    let per_noc: Vec<_> = crate::util::exec::par_map(nocs, |_, inst| {
        full_system_run_fabric(
            sys, inst, tm, schedule, fabric, grad_bytes, trace_cfg, &energy, &stall,
        )
    })
    .into_iter()
    .collect::<Result<_>>()?;
    Ok(CosimReport { per_noc })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lenet;
    use crate::noc::builder::{mesh_opt, wi_het_noc_quick};
    use crate::traffic::phases::model_phases;

    #[test]
    fn wihetnoc_beats_mesh_end_to_end() {
        let sys = SystemConfig::paper_8x8();
        let tm = model_phases(&sys, &lenet(), 32);
        let mesh = mesh_opt(&sys, true);
        let wihet = wi_het_noc_quick(&sys, 17);
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let rep = cosimulate(&sys, &tm, &[&mesh, &wihet], &cfg).unwrap();
        assert_eq!(rep.per_noc.len(), 2);
        // WiHetNoC must not be slower, and must cut EDP
        let exec = rep.exec_vs_baseline(1);
        let edp = rep.edp_vs_baseline(1);
        assert!(exec <= 1.01, "exec ratio {exec}");
        assert!(edp < 1.0, "edp ratio {edp}");
    }

    #[test]
    fn fabric_cosim_charges_every_noc() {
        let sys = SystemConfig::paper_8x8();
        let tm = model_phases(&sys, &lenet(), 32);
        let mesh = mesh_opt(&sys, true);
        let wihet = wi_het_noc_quick(&sys, 17);
        let cfg = TraceConfig { scale: 0.02, ..Default::default() };
        let fabric: Fabric = "4:topo=ring".parse().unwrap();
        let rep = cosimulate_fabric(
            &sys,
            &tm,
            &SchedulePolicy::Serial,
            &fabric,
            1 << 20,
            &[&mesh, &wihet],
            &cfg,
        )
        .unwrap();
        assert_eq!(rep.per_noc.len(), 2);
        for r in &rep.per_noc {
            assert_eq!(r.fabric_chips, 4);
            assert!(r.interchip_j > 0.0);
            assert!(r.comm_overhead_pct > 0.0);
        }
    }
}

//! L3 coordinator: drives CNN training through the PJRT runtime while
//! co-simulating the induced NoC traffic — the end-to-end loop that
//! produces the paper's full-system numbers (Fig 19).

pub mod cosim;
pub mod data;
pub mod trainer;

pub use cosim::{cosimulate, cosimulate_scheduled, CosimReport};
pub use data::SyntheticDataset;
pub use trainer::{TrainConfig, Trainer, TrainLog};

//! Wireless-interface placement [44] (§4.2.3): given the optimized
//! wireline topology and the traffic matrix, choose `n_wi` routers for
//! GPU-MC wireless interfaces so the traffic-weighted hop count is
//! minimized, then distribute them over the available channels.
//!
//! Greedy marginal-gain placement: repeatedly add the WI that most reduces
//! Σ f_ij · h_ij, where wireless-equipped routers gain single-hop edges to
//! every other WI (the channel assignment is refined afterwards round-robin
//! by traffic so each channel carries a similar load — the MAC's request
//! period grows with WIs per channel, which is what creates the paper's
//! optimum at 24 WIs / 4 channels).

use crate::noc::analysis::TrafficMatrix;
use crate::noc::topology::Topology;
use crate::noc::wireless::WirelessSpec;

/// Place `n_wi` GPU-MC WIs on `channels` channels (channel ids start at
/// `first_channel`, channel 0 being reserved for CPU-MC).
///
/// Returns WI host routers in placement order plus their channels.
pub fn place_wis(
    topo: &Topology,
    traffic: &TrafficMatrix,
    n_wi: usize,
    first_channel: usize,
    channels: usize,
) -> Vec<(usize, usize)> {
    place_wis_counted(topo, traffic, n_wi, first_channel, channels).0
}

/// [`place_wis`] plus its evaluation count: how many traffic-weighted
/// hop-count objective evaluations the greedy search spent — the
/// "wireless" stage of the design-search eval profiler
/// (`telemetry::search`). Counting is pure bookkeeping; the placement is
/// byte-identical to [`place_wis`].
pub fn place_wis_counted(
    topo: &Topology,
    traffic: &TrafficMatrix,
    n_wi: usize,
    first_channel: usize,
    channels: usize,
) -> (Vec<(usize, usize)>, u64) {
    assert!(channels >= 1);
    let mut evals = 0u64;
    let n = topo.n;
    // base all-pairs hop counts
    let mut hops = vec![0u32; n * n];
    for s in 0..n {
        let d = topo.bfs_hops(s);
        hops[s * n..(s + 1) * n].copy_from_slice(&d);
    }

    let mut wis: Vec<usize> = Vec::new();
    let mut traffic_at = vec![0.0f64; n];
    for &(s, d, f) in &traffic.entries {
        traffic_at[s as usize] += f;
        traffic_at[d as usize] += f;
    }

    for _ in 0..n_wi {
        let mut best: Option<(usize, f64)> = None;
        for cand in 0..n {
            if wis.contains(&cand) {
                continue;
            }
            let mut trial = wis.clone();
            trial.push(cand);
            evals += 1;
            let cost = twhc_with_wis(&hops, traffic, &trial, n);
            let better = match best {
                None => true,
                Some((_, bc)) => {
                    cost < bc - 1e-12
                        || (cost < bc + 1e-12
                            && traffic_at[cand] > traffic_at[best.unwrap().0])
                }
            };
            if better {
                best = Some((cand, cost));
            }
        }
        wis.push(best.expect("candidate exists").0);
    }

    // Channel assignment: order WIs by local traffic and deal them
    // round-robin so heavy WIs spread across channels.
    let mut order: Vec<usize> = (0..wis.len()).collect();
    order.sort_by(|&a, &b| {
        traffic_at[wis[b]]
            .partial_cmp(&traffic_at[wis[a]])
            .unwrap()
            .then(wis[a].cmp(&wis[b]))
    });
    let mut out = vec![(0usize, 0usize); wis.len()];
    for (rank, &idx) in order.iter().enumerate() {
        out[idx] = (wis[idx], first_channel + rank % channels);
    }
    (out, evals)
}

/// Traffic-weighted hop count when `wis` routers are pairwise connected by
/// single-hop wireless shortcuts: h'(s,d) = min(h(s,d), min_{a,b in WI}
/// h(s,a) + 1 + h(b,d)). Exact via min over WI entry/exit points.
fn twhc_with_wis(hops: &[u32], traffic: &TrafficMatrix, wis: &[usize], n: usize) -> f64 {
    let mut total = 0.0;
    for &(s, d, f) in &traffic.entries {
        let (s, d) = (s as usize, d as usize);
        let wire = hops[s * n + d];
        let mut best = wire;
        for &a in wis {
            let head = hops[s * n + a];
            if head + 1 >= best {
                continue;
            }
            for &b in wis {
                if a == b {
                    continue;
                }
                let cand = head + 1 + hops[b * n + d];
                if cand < best {
                    best = cand;
                }
            }
        }
        total += f * best as f64;
    }
    total
}

/// Build the full WiHetNoC wireless spec: one WI per CPU and per MC on the
/// dedicated channel 0, plus `n_wi` traffic-placed WIs on the remaining
/// channels.
pub fn build_wireless(
    topo: &Topology,
    traffic: &TrafficMatrix,
    cpus: &[usize],
    mcs: &[usize],
    n_wi: usize,
    gpu_channels: usize,
) -> WirelessSpec {
    build_wireless_counted(topo, traffic, cpus, mcs, n_wi, gpu_channels).0
}

/// [`build_wireless`] plus the greedy placement's evaluation count (0
/// when no GPU WIs are placed).
pub fn build_wireless_counted(
    topo: &Topology,
    traffic: &TrafficMatrix,
    cpus: &[usize],
    mcs: &[usize],
    n_wi: usize,
    gpu_channels: usize,
) -> (WirelessSpec, u64) {
    let mut spec = WirelessSpec::new(1 + gpu_channels);
    for &c in cpus {
        spec.add_wi(c, 0);
    }
    for &m in mcs {
        spec.add_wi(m, 0);
    }
    let mut evals = 0;
    if gpu_channels > 0 && n_wi > 0 {
        let (placed, e) = place_wis_counted(topo, traffic, n_wi, 1, gpu_channels);
        evals = e;
        for (router, channel) in placed {
            spec.add_wi(router, channel);
        }
    }
    (spec, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;

    fn corner_traffic(n: usize) -> TrafficMatrix {
        // heavy corner-to-corner flow: WIs should land at/near the corners
        TrafficMatrix::from_entries(n, vec![(0, 63, 10.0), (63, 0, 10.0), (3, 4, 0.1)])
    }

    #[test]
    fn wis_land_on_hot_endpoints() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let tm = corner_traffic(64);
        let placed = place_wis(&topo, &tm, 2, 1, 1);
        let routers: Vec<usize> = placed.iter().map(|p| p.0).collect();
        assert!(routers.contains(&0) && routers.contains(&63), "{routers:?}");
    }

    #[test]
    fn twhc_decreases_monotonically_with_wis() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut e = Vec::new();
        for &g in &sys.gpus() {
            for &m in &sys.mcs() {
                e.push((g as u32, m as u32, 1.0));
            }
        }
        let tm = TrafficMatrix::from_entries(64, e);
        let mut hops = vec![0u32; 64 * 64];
        for s in 0..64 {
            hops[s * 64..(s + 1) * 64].copy_from_slice(&topo.bfs_hops(s));
        }
        let mut prev = twhc_with_wis(&hops, &tm, &[], 64);
        for k in 1..=8 {
            let placed = place_wis(&topo, &tm, k, 1, 4);
            let routers: Vec<usize> = placed.iter().map(|p| p.0).collect();
            let cur = twhc_with_wis(&hops, &tm, &routers, 64);
            assert!(cur <= prev + 1e-9, "twhc up at k={k}");
            prev = cur;
        }
    }

    #[test]
    fn channels_balanced() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let tm = corner_traffic(64);
        let placed = place_wis(&topo, &tm, 8, 1, 4);
        let mut per = [0usize; 5];
        for &(_, c) in &placed {
            assert!((1..=4).contains(&c));
            per[c] += 1;
        }
        assert!(per[1..=4].iter().all(|&k| k == 2), "{per:?}");
    }

    #[test]
    fn counted_placement_is_identical_and_attributes_every_eval() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let tm = corner_traffic(64);
        let plain = place_wis(&topo, &tm, 4, 1, 2);
        let (counted, evals) = place_wis_counted(&topo, &tm, 4, 1, 2);
        assert_eq!(plain, counted, "counting must not perturb the placement");
        // greedy scans every non-WI candidate per added WI
        assert_eq!(evals, 64 + 63 + 62 + 61);
        let (spec, e) = build_wireless_counted(&topo, &tm, &sys.cpus(), &sys.mcs(), 4, 2);
        assert_eq!(e, evals);
        assert_eq!(spec.wis.len(), 8 + 4);
        let (_, zero) = build_wireless_counted(&topo, &tm, &sys.cpus(), &sys.mcs(), 0, 2);
        assert_eq!(zero, 0);
    }

    #[test]
    fn full_spec_has_dedicated_cpu_channel() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let tm = corner_traffic(64);
        let spec = build_wireless(&topo, &tm, &sys.cpus(), &sys.mcs(), 8, 4);
        assert_eq!(spec.on_channel(0).len(), 8); // 4 CPU + 4 MC
        assert_eq!(spec.wis.len(), 16);
        for &c in &sys.cpus() {
            assert!(spec.wi_at(c, 0).is_some());
        }
    }
}

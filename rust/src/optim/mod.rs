//! Multi-objective design-space exploration: AMOSA (Archived Multi-
//! Objective Simulated Annealing [43]) plus the paper's three placement
//! problems — irregular wireline connectivity (Eqns 6-9), CPU/MC tile
//! placement on the mesh, and wireless-interface placement [44].

pub mod amosa;
pub mod linkplace;
pub mod placement;
pub mod wiplace;

pub use amosa::{Amosa, AmosaConfig, Archived, Problem};
pub use linkplace::{LinkPlacement, LinkSolution};
pub use placement::optimize_placement;
pub use wiplace::place_wis;

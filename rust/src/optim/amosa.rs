//! AMOSA — Archived Multi-Objective Simulated Annealing
//! (Bandyopadhyay, Saha, Maulik, Deb [43]).
//!
//! Generic over a `Problem` (solution type + objective vector + perturb).
//! The archive keeps mutually non-dominating solutions; acceptance of a
//! perturbed solution follows the paper's amount-of-domination rule:
//!
//!   Δdom(a, b) = Π_i |f_i(a) - f_i(b)| / R_i   over objectives where they
//!   differ, with R_i the objective range observed in the archive.
//!
//! All objectives are minimized. When the archive exceeds `hard_limit` it
//! is thinned to `soft_limit` by greedy nearest-pair clustering in
//! objective space.
//!
//! # Observer contract
//!
//! [`Amosa::run_observed`] takes an `Option<&mut SearchObserver>` — the
//! same zero-overhead idiom as the simulator's `Option<&mut Telemetry>`
//! hooks. With `None` every hook is a never-taken branch and
//! [`Amosa::run`] is byte-identical to the unobserved annealer. With an
//! observer attached the hooks are **strictly read-only**: they never
//! draw from the annealer's [`Rng`], never touch the archive or the
//! current point, and never change an acceptance decision — so the
//! designed solution is byte-identical with or without one (pinned by
//! `tests/search_obs.rs`). The observer sees
//!
//! * every evaluated objective vector (it maintains its own best-so-far
//!   non-dominated front, so its hypervolume series is monotone
//!   non-decreasing by construction — archive clustering can shrink the
//!   *archive's* front, never the observer's),
//! * every acceptance verdict (accepted / uphill-accepted / rejected,
//!   plus dominated-candidate and archive-insertion counts),
//! * one [`LevelStats`] snapshot per temperature level: temperature,
//!   cumulative evals, the verdict counters, archive size, objective
//!   ranges, deterministic hypervolume vs a fixed reference point, and
//!   the Pareto-archive objective vectors at that cooling step.
//!
//! The reference point is fixed once, from the seed archive (component
//! max over finite seed objectives plus a 25% span margin), so
//! hypervolume is comparable across levels and deterministic given the
//! seed.

use crate::util::rng::Rng;

/// A multi-objective optimization problem. Objectives are minimized.
pub trait Problem {
    type Sol: Clone;

    /// Number of objectives (constant).
    fn num_objectives(&self) -> usize;

    /// Evaluate the objective vector into `out` (`out.len() ==
    /// num_objectives()`). This is the annealer's inner loop — called
    /// ~10^5 times per design — so implementations write into the
    /// caller's buffer instead of allocating a `Vec` per evaluation.
    fn objectives_into(&self, sol: &Self::Sol, out: &mut [f64]);

    /// Convenience allocating wrapper around [`Problem::objectives_into`].
    fn objectives(&self, sol: &Self::Sol) -> Vec<f64> {
        let mut out = vec![0.0; self.num_objectives()];
        self.objectives_into(sol, &mut out);
        out
    }

    /// Produce a random feasible neighbor.
    fn perturb(&self, sol: &Self::Sol, rng: &mut Rng) -> Self::Sol;

    /// A random feasible starting solution.
    fn initial(&self, rng: &mut Rng) -> Self::Sol;
}

#[derive(Debug, Clone)]
pub struct AmosaConfig {
    pub initial_temp: f64,
    pub final_temp: f64,
    /// Geometric cooling factor per temperature level.
    pub cooling: f64,
    /// Perturbations per temperature level.
    pub iters_per_temp: usize,
    pub soft_limit: usize,
    pub hard_limit: usize,
    pub seed: u64,
}

impl Default for AmosaConfig {
    fn default() -> Self {
        AmosaConfig {
            initial_temp: 100.0,
            final_temp: 0.01,
            cooling: 0.9,
            iters_per_temp: 500,
            soft_limit: 24,
            hard_limit: 36,
            seed: 0xA05A,
        }
    }
}

/// An archived solution with its objective vector.
#[derive(Debug, Clone)]
pub struct Archived<S> {
    pub sol: S,
    pub obj: Vec<f64>,
}

/// `a` dominates `b` (all objectives <=, at least one <).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// One per-temperature-level convergence snapshot (see the module docs'
/// observer contract). `front` is the Pareto-archive snapshot: the
/// objective vectors of every archive member at the end of the level.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelStats {
    /// Temperature-level index (0 = hottest).
    pub level: usize,
    pub temp: f64,
    /// Cumulative problem evaluations at the end of this level
    /// (including the seed-archive evaluations).
    pub evals: u64,
    /// Candidates accepted this level (deterministic + uphill).
    pub accepted: u64,
    /// Of those, probabilistic amount-of-domination acceptances.
    pub accepted_uphill: u64,
    /// Candidates rejected this level.
    pub rejected: u64,
    /// Candidates dominated by the current point or an archive member.
    pub dominated: u64,
    /// Candidates that actually entered the archive this level.
    pub archived: u64,
    pub archive_len: usize,
    /// Componentwise objective minima over the archive.
    pub obj_min: Vec<f64>,
    /// Componentwise objective maxima over the archive.
    pub obj_max: Vec<f64>,
    /// Hypervolume of the observer's best-so-far front vs the fixed
    /// reference point (exact for 2 objectives, 0.0 otherwise).
    pub hypervolume: f64,
    /// Pareto-archive snapshot: archive objective vectors at this level.
    pub front: Vec<Vec<f64>>,
}

/// Read-only convergence recorder for one [`Amosa::run_observed`] pass.
/// See the module docs for the contract; package a finished observer
/// into a [`crate::telemetry::search::SearchTrace`] stage for export.
#[derive(Debug, Clone, Default)]
pub struct SearchObserver {
    /// One snapshot per temperature level, in cooling order.
    pub levels: Vec<LevelStats>,
    /// Fixed hypervolume reference point, derived from the seed archive
    /// at [`Amosa::run_observed`] start (empty until then, or forever if
    /// no seed solution evaluated finite).
    pub ref_point: Vec<f64>,
    /// Best-so-far non-dominated front over *every* finite evaluation —
    /// grows monotonically in coverage, unlike the clustered archive.
    front: Vec<Vec<f64>>,
    accepted: u64,
    accepted_uphill: u64,
    rejected: u64,
    dominated: u64,
    archived: u64,
}

impl SearchObserver {
    pub fn new() -> SearchObserver {
        SearchObserver::default()
    }

    /// Total evaluations recorded (cumulative count of the last level).
    pub fn evals(&self) -> u64 {
        self.levels.last().map_or(0, |l| l.evals)
    }

    /// The best-so-far non-dominated front (objective vectors).
    pub fn best_front(&self) -> &[Vec<f64>] {
        &self.front
    }

    /// Fix the reference point from the seed archive: componentwise max
    /// over finite members plus a 25% span margin. No finite seed member
    /// leaves it empty (hypervolume stays 0.0).
    fn start(&mut self, seed_objs: &[&[f64]], m: usize) {
        self.levels.clear();
        self.front.clear();
        self.ref_point.clear();
        self.reset_counters();
        let finite: Vec<&&[f64]> =
            seed_objs.iter().filter(|o| o.iter().all(|v| v.is_finite())).collect();
        if !finite.is_empty() {
            for i in 0..m {
                let lo = finite.iter().fold(f64::INFINITY, |a, o| a.min(o[i]));
                let hi = finite.iter().fold(f64::NEG_INFINITY, |a, o| a.max(o[i]));
                self.ref_point.push(hi + 0.25 * (hi - lo).max(1e-9));
            }
        }
        for o in seed_objs {
            self.saw(o);
        }
    }

    fn reset_counters(&mut self) {
        self.accepted = 0;
        self.accepted_uphill = 0;
        self.rejected = 0;
        self.dominated = 0;
        self.archived = 0;
    }

    /// An objective vector was evaluated: fold it into the best-so-far
    /// front (non-finite vectors — infeasibility fences — are ignored).
    fn saw(&mut self, obj: &[f64]) {
        if !obj.iter().all(|v| v.is_finite()) {
            return;
        }
        if self.front.iter().any(|f| dominates(f, obj) || f[..] == *obj) {
            return;
        }
        self.front.retain(|f| !dominates(obj, f));
        self.front.push(obj.to_vec());
    }

    fn verdict(&mut self, accepted: bool, uphill: bool, dominated: bool) {
        if accepted {
            self.accepted += 1;
            if uphill {
                self.accepted_uphill += 1;
            }
        } else {
            self.rejected += 1;
        }
        if dominated {
            self.dominated += 1;
        }
    }

    fn archived(&mut self) {
        self.archived += 1;
    }

    /// Close a temperature level: snapshot the counters, the archive
    /// front, and the best-so-far hypervolume.
    fn level_end(&mut self, temp: f64, evals: u64, archive_objs: &[&[f64]]) {
        let m = archive_objs.first().map_or(0, |o| o.len());
        let mut obj_min = vec![f64::INFINITY; m];
        let mut obj_max = vec![f64::NEG_INFINITY; m];
        for o in archive_objs {
            for i in 0..m {
                obj_min[i] = obj_min[i].min(o[i]);
                obj_max[i] = obj_max[i].max(o[i]);
            }
        }
        self.levels.push(LevelStats {
            level: self.levels.len(),
            temp,
            evals,
            accepted: self.accepted,
            accepted_uphill: self.accepted_uphill,
            rejected: self.rejected,
            dominated: self.dominated,
            archived: self.archived,
            archive_len: archive_objs.len(),
            obj_min,
            obj_max,
            hypervolume: hypervolume_2d(&self.front, &self.ref_point),
            front: archive_objs.iter().map(|o| o.to_vec()).collect(),
        });
        self.reset_counters();
    }
}

/// Exact 2-objective hypervolume of a minimization front w.r.t. a
/// reference point: the area dominated by the front inside the box
/// `[min, ref)`. Points not strictly dominating `ref_point` contribute
/// nothing. Returns 0.0 for other objective counts (every problem in
/// this crate is biobjective) or an unset reference.
pub fn hypervolume_2d(front: &[Vec<f64>], ref_point: &[f64]) -> f64 {
    if ref_point.len() != 2 {
        return 0.0;
    }
    let mut pts: Vec<(f64, f64)> = front
        .iter()
        .filter(|o| o.len() == 2 && o[0] < ref_point[0] && o[1] < ref_point[1])
        .map(|o| (o[0], o[1]))
        .collect();
    // sweep by ascending f0; a non-dominated front then has strictly
    // descending f1, and each point owns the rectangle down to the next
    pts.sort_by(|a, b| a.partial_cmp(b).expect("finite objectives"));
    let mut hv = 0.0;
    let mut best1 = ref_point[1];
    for (x, y) in pts {
        if y < best1 {
            hv += (ref_point[0] - x) * (best1 - y);
            best1 = y;
        }
    }
    hv
}

pub struct Amosa<'p, P: Problem> {
    pub problem: &'p P,
    pub cfg: AmosaConfig,
    pub archive: Vec<Archived<P::Sol>>,
    pub evaluations: u64,
}

impl<'p, P: Problem> Amosa<'p, P> {
    pub fn new(problem: &'p P, cfg: AmosaConfig) -> Self {
        Amosa { problem, cfg, archive: Vec::new(), evaluations: 0 }
    }

    /// Run the full annealing schedule; returns the final archive (the
    /// near-Pareto front).
    ///
    /// §Perf: the candidate objective vector and the normalization ranges
    /// live in two buffers reused across all iterations — an `Archived`
    /// (and its owned `Vec`) is built only when a candidate is actually
    /// accepted or archived.
    pub fn run(&mut self) -> &[Archived<P::Sol>] {
        self.run_observed(None)
    }

    /// [`Amosa::run`] with an optional read-only [`SearchObserver`]
    /// attached (see the module docs for the contract). `None` takes the
    /// exact same code path as `run`; `Some` records convergence
    /// snapshots without perturbing a single acceptance decision or RNG
    /// draw, so the returned archive is byte-identical either way.
    pub fn run_observed(
        &mut self,
        mut obs: Option<&mut SearchObserver>,
    ) -> &[Archived<P::Sol>] {
        let mut rng = Rng::new(self.cfg.seed);
        let m = self.problem.num_objectives();
        let mut cand_obj = vec![0.0; m];
        let mut ranges = vec![0.0; m];
        // Seed archive with a few random solutions.
        let mut seed_objs: Vec<Vec<f64>> = Vec::new();
        for _ in 0..self.cfg.soft_limit.min(8) {
            let s = self.problem.initial(&mut rng);
            self.evaluations += 1;
            self.problem.objectives_into(&s, &mut cand_obj);
            if obs.is_some() {
                seed_objs.push(cand_obj.clone());
            }
            self.add_to_archive(Archived { sol: s, obj: cand_obj.clone() });
        }
        if let Some(o) = obs.as_deref_mut() {
            let views: Vec<&[f64]> = seed_objs.iter().map(|v| v.as_slice()).collect();
            o.start(&views, m);
        }
        let mut current = self.archive[rng.below(self.archive.len())].clone();

        let mut temp = self.cfg.initial_temp;
        while temp > self.cfg.final_temp {
            for _ in 0..self.cfg.iters_per_temp {
                let cand_sol = self.problem.perturb(&current.sol, &mut rng);
                self.evaluations += 1;
                self.problem.objectives_into(&cand_sol, &mut cand_obj);
                if let Some(o) = obs.as_deref_mut() {
                    o.saw(&cand_obj);
                }
                self.objective_ranges_into(&mut ranges);
                current = self.step(
                    current,
                    cand_sol,
                    &cand_obj,
                    &ranges,
                    temp,
                    &mut rng,
                    obs.as_deref_mut(),
                );
            }
            if let Some(o) = obs.as_deref_mut() {
                let archive_objs: Vec<&[f64]> =
                    self.archive.iter().map(|a| a.obj.as_slice()).collect();
                o.level_end(temp, self.evaluations, &archive_objs);
            }
            temp *= self.cfg.cooling;
        }
        &self.archive
    }

    /// One AMOSA acceptance step; returns the (possibly new) current point.
    /// `cand_obj`/`ranges` are borrowed scratch — the candidate is only
    /// materialized as an `Archived` on acceptance. The observer hooks
    /// record the verdict but never influence it.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &mut self,
        current: Archived<P::Sol>,
        cand_sol: P::Sol,
        cand_obj: &[f64],
        ranges: &[f64],
        temp: f64,
        rng: &mut Rng,
        mut obs: Option<&mut SearchObserver>,
    ) -> Archived<P::Sol> {
        if dominates(&current.obj, cand_obj) {
            // current (and possibly archive members) dominate the candidate:
            // accept with probability from average amount-of-domination.
            let mut dom_sum = delta_dom(&current.obj, cand_obj, ranges);
            let mut k = 1;
            for a in &self.archive {
                if dominates(&a.obj, cand_obj) {
                    dom_sum += delta_dom(&a.obj, cand_obj, ranges);
                    k += 1;
                }
            }
            let avg = dom_sum / k as f64;
            let p = 1.0 / (1.0 + (avg * temp).exp());
            let take = rng.chance(p);
            if let Some(o) = obs.as_deref_mut() {
                o.verdict(take, take, true);
            }
            if take {
                Archived { sol: cand_sol, obj: cand_obj.to_vec() }
            } else {
                current
            }
        } else if dominates(cand_obj, &current.obj) {
            // candidate dominates current: accept; archive-dominance decides
            // whether it also enters the archive.
            let cand = Archived { sol: cand_sol, obj: cand_obj.to_vec() };
            let inserted = self.add_to_archive(cand.clone());
            if let Some(o) = obs.as_deref_mut() {
                o.verdict(true, false, false);
                if inserted {
                    o.archived();
                }
            }
            cand
        } else {
            // mutually non-dominating w.r.t. current.
            let dominated_by_archive = self
                .archive
                .iter()
                .filter(|a| dominates(&a.obj, cand_obj))
                .count();
            if dominated_by_archive > 0 {
                let avg = self
                    .archive
                    .iter()
                    .filter(|a| dominates(&a.obj, cand_obj))
                    .map(|a| delta_dom(&a.obj, cand_obj, ranges))
                    .sum::<f64>()
                    / dominated_by_archive as f64;
                let p = 1.0 / (1.0 + (avg * temp).exp());
                let take = rng.chance(p);
                if let Some(o) = obs.as_deref_mut() {
                    o.verdict(take, take, true);
                }
                if take {
                    Archived { sol: cand_sol, obj: cand_obj.to_vec() }
                } else {
                    current
                }
            } else {
                let cand = Archived { sol: cand_sol, obj: cand_obj.to_vec() };
                let inserted = self.add_to_archive(cand.clone());
                if let Some(o) = obs.as_deref_mut() {
                    o.verdict(true, false, false);
                    if inserted {
                        o.archived();
                    }
                }
                cand
            }
        }
    }

    fn objective_ranges_into(&self, out: &mut [f64]) {
        // objective-major over a bounded archive (<= hard_limit entries):
        // allocation-free for any objective count
        for (i, o) in out.iter_mut().enumerate() {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for a in &self.archive {
                lo = lo.min(a.obj[i]);
                hi = hi.max(a.obj[i]);
            }
            *o = (hi - lo).max(1e-12);
        }
    }

    fn objective_ranges(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.problem.num_objectives()];
        self.objective_ranges_into(&mut out);
        out
    }

    /// Insert and keep the archive mutually non-dominating. Returns
    /// whether the candidate actually entered (a dominated or duplicate
    /// candidate is dropped).
    pub fn add_to_archive(&mut self, cand: Archived<P::Sol>) -> bool {
        if self
            .archive
            .iter()
            .any(|a| dominates(&a.obj, &cand.obj) || a.obj == cand.obj)
        {
            return false;
        }
        self.archive.retain(|a| !dominates(&cand.obj, &a.obj));
        self.archive.push(cand);
        if self.archive.len() > self.cfg.hard_limit {
            self.cluster_to(self.cfg.soft_limit);
        }
        true
    }

    /// Greedy clustering: repeatedly merge the closest pair (in normalized
    /// objective space), keeping the member closer to the pair centroid.
    fn cluster_to(&mut self, target: usize) {
        let ranges = self.objective_ranges();
        while self.archive.len() > target {
            let mut best = (0usize, 1usize, f64::INFINITY);
            for i in 0..self.archive.len() {
                for j in (i + 1)..self.archive.len() {
                    let d = dist(&self.archive[i].obj, &self.archive[j].obj, &ranges);
                    if d < best.2 {
                        best = (i, j, d);
                    }
                }
            }
            // drop the member of the closest pair with the more crowded
            // neighborhood (approximate: drop j)
            self.archive.swap_remove(best.1);
        }
    }

    /// Best archive member by scalarization weight `w` over objectives.
    pub fn best_by(&self, w: &[f64]) -> &Archived<P::Sol> {
        self.archive
            .iter()
            .min_by(|a, b| {
                let sa: f64 = a.obj.iter().zip(w).map(|(o, w)| o * w).sum();
                let sb: f64 = b.obj.iter().zip(w).map(|(o, w)| o * w).sum();
                sa.partial_cmp(&sb).unwrap()
            })
            .expect("archive nonempty")
    }
}

fn delta_dom(a: &[f64], b: &[f64], ranges: &[f64]) -> f64 {
    let mut prod = 1.0;
    for i in 0..a.len() {
        let d = (a[i] - b[i]).abs() / ranges[i];
        if d > 0.0 {
            prod *= d;
        }
    }
    prod
}

fn dist(a: &[f64], b: &[f64], ranges: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .zip(ranges)
        .map(|((x, y), r)| ((x - y) / r).powi(2))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy biobjective problem: minimize (x^2, (x-2)^2) over x in [-5, 5];
    /// Pareto front is x in [0, 2].
    struct Toy;

    impl Problem for Toy {
        type Sol = f64;

        fn num_objectives(&self) -> usize {
            2
        }

        fn objectives_into(&self, x: &f64, out: &mut [f64]) {
            out[0] = x * x;
            out[1] = (x - 2.0) * (x - 2.0);
        }

        fn perturb(&self, x: &f64, rng: &mut Rng) -> f64 {
            (x + (rng.f64() - 0.5)).clamp(-5.0, 5.0)
        }

        fn initial(&self, rng: &mut Rng) -> f64 {
            rng.f64() * 10.0 - 5.0
        }
    }

    #[test]
    fn dominance() {
        assert!(dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 2.0], &[1.0, 2.0]));
    }

    #[test]
    fn archive_stays_nondominated() {
        let p = Toy;
        let mut a = Amosa::new(&p, AmosaConfig { iters_per_temp: 50, ..Default::default() });
        a.run();
        for i in 0..a.archive.len() {
            for j in 0..a.archive.len() {
                if i != j {
                    assert!(!dominates(&a.archive[i].obj, &a.archive[j].obj));
                }
            }
        }
    }

    #[test]
    fn converges_to_pareto_front() {
        let p = Toy;
        let mut a = Amosa::new(&p, AmosaConfig::default());
        a.run();
        assert!(!a.archive.is_empty());
        // all archive solutions should sit near [0, 2]
        for m in &a.archive {
            assert!(
                (-0.25..=2.25).contains(&m.sol),
                "solution {} not near Pareto set",
                m.sol
            );
        }
        // the extremes should be approached
        let best0 = a.best_by(&[1.0, 0.0]);
        assert!(best0.obj[0] < 0.1, "min f0 {:?}", best0.obj);
        let best1 = a.best_by(&[0.0, 1.0]);
        assert!(best1.obj[1] < 0.1, "min f1 {:?}", best1.obj);
    }

    #[test]
    fn hard_limit_respected() {
        let p = Toy;
        let cfg = AmosaConfig { soft_limit: 5, hard_limit: 8, ..Default::default() };
        let mut a = Amosa::new(&p, cfg);
        a.run();
        assert!(a.archive.len() <= 8);
    }

    #[test]
    fn hypervolume_2d_exact_on_known_fronts() {
        let r = [4.0, 4.0];
        // single point: one rectangle
        assert_eq!(hypervolume_2d(&[vec![1.0, 1.0]], &r), 9.0);
        // staircase: (1,2) and (2,1) — union of rectangles, overlap once
        let hv = hypervolume_2d(&[vec![1.0, 2.0], vec![2.0, 1.0]], &r);
        assert!((hv - 8.0).abs() < 1e-12, "{hv}");
        // point outside the reference box contributes nothing
        assert_eq!(hypervolume_2d(&[vec![5.0, 5.0]], &r), 0.0);
        // order-independent
        let ba = hypervolume_2d(&[vec![2.0, 1.0], vec![1.0, 2.0]], &r);
        assert_eq!(hv, ba);
        // unset / wrong-arity reference
        assert_eq!(hypervolume_2d(&[vec![1.0, 1.0]], &[]), 0.0);
    }

    #[test]
    fn observer_is_neutral_and_levels_account_for_every_eval() {
        let p = Toy;
        let cfg = AmosaConfig { iters_per_temp: 40, ..Default::default() };
        let mut plain = Amosa::new(&p, cfg.clone());
        plain.run();
        let reference: Vec<f64> = plain.archive.iter().map(|m| m.sol).collect();

        let mut observed = Amosa::new(&p, cfg.clone());
        let mut obs = SearchObserver::new();
        observed.run_observed(Some(&mut obs));
        let with_obs: Vec<f64> = observed.archive.iter().map(|m| m.sol).collect();
        assert_eq!(reference, with_obs, "observer perturbed the archive");
        assert_eq!(plain.evaluations, observed.evaluations);

        // one snapshot per temperature level, evals fully attributed
        assert!(!obs.levels.is_empty());
        assert_eq!(obs.evals(), observed.evaluations);
        let mut expect = 8u64; // seed evaluations
        for l in &obs.levels {
            expect += cfg.iters_per_temp as u64;
            assert_eq!(l.evals, expect, "level {} evals", l.level);
            assert_eq!(
                l.accepted + l.rejected,
                cfg.iters_per_temp as u64,
                "level {} verdicts", l.level
            );
            assert!(l.accepted_uphill <= l.accepted);
            assert_eq!(l.archive_len, l.front.len());
            assert!(l.archive_len >= 1);
            for (lo, hi) in l.obj_min.iter().zip(&l.obj_max) {
                assert!(lo <= hi);
            }
        }
        // temperatures cool geometrically across snapshots
        for w in obs.levels.windows(2) {
            assert!(w[1].temp < w[0].temp);
        }
    }

    #[test]
    fn observer_hypervolume_is_monotone_nondecreasing() {
        let p = Toy;
        let mut a = Amosa::new(&p, AmosaConfig { iters_per_temp: 60, ..Default::default() });
        let mut obs = SearchObserver::new();
        a.run_observed(Some(&mut obs));
        assert_eq!(obs.ref_point.len(), 2);
        let hv: Vec<f64> = obs.levels.iter().map(|l| l.hypervolume).collect();
        assert!(hv.last().copied().unwrap() > 0.0, "{hv:?}");
        for w in hv.windows(2) {
            assert!(w[1] >= w[0], "hypervolume decreased: {hv:?}");
        }
        // the best-so-far front is itself non-dominated
        let f = obs.best_front();
        for i in 0..f.len() {
            for j in 0..f.len() {
                assert!(i == j || !dominates(&f[i], &f[j]));
            }
        }
    }

    #[test]
    fn observed_rerun_is_deterministic() {
        let p = Toy;
        let snap = |seed| {
            let mut a = Amosa::new(
                &p,
                AmosaConfig { seed, iters_per_temp: 20, ..Default::default() },
            );
            let mut obs = SearchObserver::new();
            a.run_observed(Some(&mut obs));
            format!("{obs:?}")
        };
        assert_eq!(snap(13), snap(13));
    }

    #[test]
    fn deterministic_given_seed() {
        let p = Toy;
        let run = |seed| {
            let mut a = Amosa::new(
                &p,
                AmosaConfig { seed, iters_per_temp: 20, ..Default::default() },
            );
            a.run();
            a.archive.iter().map(|m| m.sol).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
    }
}

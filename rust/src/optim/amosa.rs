//! AMOSA — Archived Multi-Objective Simulated Annealing
//! (Bandyopadhyay, Saha, Maulik, Deb [43]).
//!
//! Generic over a `Problem` (solution type + objective vector + perturb).
//! The archive keeps mutually non-dominating solutions; acceptance of a
//! perturbed solution follows the paper's amount-of-domination rule:
//!
//!   Δdom(a, b) = Π_i |f_i(a) - f_i(b)| / R_i   over objectives where they
//!   differ, with R_i the objective range observed in the archive.
//!
//! All objectives are minimized. When the archive exceeds `hard_limit` it
//! is thinned to `soft_limit` by greedy nearest-pair clustering in
//! objective space.

use crate::util::rng::Rng;

/// A multi-objective optimization problem. Objectives are minimized.
pub trait Problem {
    type Sol: Clone;

    /// Number of objectives (constant).
    fn num_objectives(&self) -> usize;

    /// Evaluate the objective vector into `out` (`out.len() ==
    /// num_objectives()`). This is the annealer's inner loop — called
    /// ~10^5 times per design — so implementations write into the
    /// caller's buffer instead of allocating a `Vec` per evaluation.
    fn objectives_into(&self, sol: &Self::Sol, out: &mut [f64]);

    /// Convenience allocating wrapper around [`Problem::objectives_into`].
    fn objectives(&self, sol: &Self::Sol) -> Vec<f64> {
        let mut out = vec![0.0; self.num_objectives()];
        self.objectives_into(sol, &mut out);
        out
    }

    /// Produce a random feasible neighbor.
    fn perturb(&self, sol: &Self::Sol, rng: &mut Rng) -> Self::Sol;

    /// A random feasible starting solution.
    fn initial(&self, rng: &mut Rng) -> Self::Sol;
}

#[derive(Debug, Clone)]
pub struct AmosaConfig {
    pub initial_temp: f64,
    pub final_temp: f64,
    /// Geometric cooling factor per temperature level.
    pub cooling: f64,
    /// Perturbations per temperature level.
    pub iters_per_temp: usize,
    pub soft_limit: usize,
    pub hard_limit: usize,
    pub seed: u64,
}

impl Default for AmosaConfig {
    fn default() -> Self {
        AmosaConfig {
            initial_temp: 100.0,
            final_temp: 0.01,
            cooling: 0.9,
            iters_per_temp: 500,
            soft_limit: 24,
            hard_limit: 36,
            seed: 0xA05A,
        }
    }
}

/// An archived solution with its objective vector.
#[derive(Debug, Clone)]
pub struct Archived<S> {
    pub sol: S,
    pub obj: Vec<f64>,
}

/// `a` dominates `b` (all objectives <=, at least one <).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

pub struct Amosa<'p, P: Problem> {
    pub problem: &'p P,
    pub cfg: AmosaConfig,
    pub archive: Vec<Archived<P::Sol>>,
    pub evaluations: u64,
}

impl<'p, P: Problem> Amosa<'p, P> {
    pub fn new(problem: &'p P, cfg: AmosaConfig) -> Self {
        Amosa { problem, cfg, archive: Vec::new(), evaluations: 0 }
    }

    /// Run the full annealing schedule; returns the final archive (the
    /// near-Pareto front).
    ///
    /// §Perf: the candidate objective vector and the normalization ranges
    /// live in two buffers reused across all iterations — an `Archived`
    /// (and its owned `Vec`) is built only when a candidate is actually
    /// accepted or archived.
    pub fn run(&mut self) -> &[Archived<P::Sol>] {
        let mut rng = Rng::new(self.cfg.seed);
        let m = self.problem.num_objectives();
        let mut cand_obj = vec![0.0; m];
        let mut ranges = vec![0.0; m];
        // Seed archive with a few random solutions.
        for _ in 0..self.cfg.soft_limit.min(8) {
            let s = self.problem.initial(&mut rng);
            self.evaluations += 1;
            self.problem.objectives_into(&s, &mut cand_obj);
            self.add_to_archive(Archived { sol: s, obj: cand_obj.clone() });
        }
        let mut current = self.archive[rng.below(self.archive.len())].clone();

        let mut temp = self.cfg.initial_temp;
        while temp > self.cfg.final_temp {
            for _ in 0..self.cfg.iters_per_temp {
                let cand_sol = self.problem.perturb(&current.sol, &mut rng);
                self.evaluations += 1;
                self.problem.objectives_into(&cand_sol, &mut cand_obj);
                self.objective_ranges_into(&mut ranges);
                current = self.step(current, cand_sol, &cand_obj, &ranges, temp, &mut rng);
            }
            temp *= self.cfg.cooling;
        }
        &self.archive
    }

    /// One AMOSA acceptance step; returns the (possibly new) current point.
    /// `cand_obj`/`ranges` are borrowed scratch — the candidate is only
    /// materialized as an `Archived` on acceptance.
    fn step(
        &mut self,
        current: Archived<P::Sol>,
        cand_sol: P::Sol,
        cand_obj: &[f64],
        ranges: &[f64],
        temp: f64,
        rng: &mut Rng,
    ) -> Archived<P::Sol> {
        if dominates(&current.obj, cand_obj) {
            // current (and possibly archive members) dominate the candidate:
            // accept with probability from average amount-of-domination.
            let mut dom_sum = delta_dom(&current.obj, cand_obj, ranges);
            let mut k = 1;
            for a in &self.archive {
                if dominates(&a.obj, cand_obj) {
                    dom_sum += delta_dom(&a.obj, cand_obj, ranges);
                    k += 1;
                }
            }
            let avg = dom_sum / k as f64;
            let p = 1.0 / (1.0 + (avg * temp).exp());
            if rng.chance(p) {
                Archived { sol: cand_sol, obj: cand_obj.to_vec() }
            } else {
                current
            }
        } else if dominates(cand_obj, &current.obj) {
            // candidate dominates current: accept; archive-dominance decides
            // whether it also enters the archive.
            let cand = Archived { sol: cand_sol, obj: cand_obj.to_vec() };
            self.add_to_archive(cand.clone());
            cand
        } else {
            // mutually non-dominating w.r.t. current.
            let dominated_by_archive = self
                .archive
                .iter()
                .filter(|a| dominates(&a.obj, cand_obj))
                .count();
            if dominated_by_archive > 0 {
                let avg = self
                    .archive
                    .iter()
                    .filter(|a| dominates(&a.obj, cand_obj))
                    .map(|a| delta_dom(&a.obj, cand_obj, ranges))
                    .sum::<f64>()
                    / dominated_by_archive as f64;
                let p = 1.0 / (1.0 + (avg * temp).exp());
                if rng.chance(p) {
                    Archived { sol: cand_sol, obj: cand_obj.to_vec() }
                } else {
                    current
                }
            } else {
                let cand = Archived { sol: cand_sol, obj: cand_obj.to_vec() };
                self.add_to_archive(cand.clone());
                cand
            }
        }
    }

    fn objective_ranges_into(&self, out: &mut [f64]) {
        // objective-major over a bounded archive (<= hard_limit entries):
        // allocation-free for any objective count
        for (i, o) in out.iter_mut().enumerate() {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for a in &self.archive {
                lo = lo.min(a.obj[i]);
                hi = hi.max(a.obj[i]);
            }
            *o = (hi - lo).max(1e-12);
        }
    }

    fn objective_ranges(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.problem.num_objectives()];
        self.objective_ranges_into(&mut out);
        out
    }

    /// Insert and keep the archive mutually non-dominating.
    pub fn add_to_archive(&mut self, cand: Archived<P::Sol>) {
        if self
            .archive
            .iter()
            .any(|a| dominates(&a.obj, &cand.obj) || a.obj == cand.obj)
        {
            return;
        }
        self.archive.retain(|a| !dominates(&cand.obj, &a.obj));
        self.archive.push(cand);
        if self.archive.len() > self.cfg.hard_limit {
            self.cluster_to(self.cfg.soft_limit);
        }
    }

    /// Greedy clustering: repeatedly merge the closest pair (in normalized
    /// objective space), keeping the member closer to the pair centroid.
    fn cluster_to(&mut self, target: usize) {
        let ranges = self.objective_ranges();
        while self.archive.len() > target {
            let mut best = (0usize, 1usize, f64::INFINITY);
            for i in 0..self.archive.len() {
                for j in (i + 1)..self.archive.len() {
                    let d = dist(&self.archive[i].obj, &self.archive[j].obj, &ranges);
                    if d < best.2 {
                        best = (i, j, d);
                    }
                }
            }
            // drop the member of the closest pair with the more crowded
            // neighborhood (approximate: drop j)
            self.archive.swap_remove(best.1);
        }
    }

    /// Best archive member by scalarization weight `w` over objectives.
    pub fn best_by(&self, w: &[f64]) -> &Archived<P::Sol> {
        self.archive
            .iter()
            .min_by(|a, b| {
                let sa: f64 = a.obj.iter().zip(w).map(|(o, w)| o * w).sum();
                let sb: f64 = b.obj.iter().zip(w).map(|(o, w)| o * w).sum();
                sa.partial_cmp(&sb).unwrap()
            })
            .expect("archive nonempty")
    }
}

fn delta_dom(a: &[f64], b: &[f64], ranges: &[f64]) -> f64 {
    let mut prod = 1.0;
    for i in 0..a.len() {
        let d = (a[i] - b[i]).abs() / ranges[i];
        if d > 0.0 {
            prod *= d;
        }
    }
    prod
}

fn dist(a: &[f64], b: &[f64], ranges: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .zip(ranges)
        .map(|((x, y), r)| ((x - y) / r).powi(2))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy biobjective problem: minimize (x^2, (x-2)^2) over x in [-5, 5];
    /// Pareto front is x in [0, 2].
    struct Toy;

    impl Problem for Toy {
        type Sol = f64;

        fn num_objectives(&self) -> usize {
            2
        }

        fn objectives_into(&self, x: &f64, out: &mut [f64]) {
            out[0] = x * x;
            out[1] = (x - 2.0) * (x - 2.0);
        }

        fn perturb(&self, x: &f64, rng: &mut Rng) -> f64 {
            (x + (rng.f64() - 0.5)).clamp(-5.0, 5.0)
        }

        fn initial(&self, rng: &mut Rng) -> f64 {
            rng.f64() * 10.0 - 5.0
        }
    }

    #[test]
    fn dominance() {
        assert!(dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 2.0], &[1.0, 2.0]));
    }

    #[test]
    fn archive_stays_nondominated() {
        let p = Toy;
        let mut a = Amosa::new(&p, AmosaConfig { iters_per_temp: 50, ..Default::default() });
        a.run();
        for i in 0..a.archive.len() {
            for j in 0..a.archive.len() {
                if i != j {
                    assert!(!dominates(&a.archive[i].obj, &a.archive[j].obj));
                }
            }
        }
    }

    #[test]
    fn converges_to_pareto_front() {
        let p = Toy;
        let mut a = Amosa::new(&p, AmosaConfig::default());
        a.run();
        assert!(!a.archive.is_empty());
        // all archive solutions should sit near [0, 2]
        for m in &a.archive {
            assert!(
                (-0.25..=2.25).contains(&m.sol),
                "solution {} not near Pareto set",
                m.sol
            );
        }
        // the extremes should be approached
        let best0 = a.best_by(&[1.0, 0.0]);
        assert!(best0.obj[0] < 0.1, "min f0 {:?}", best0.obj);
        let best1 = a.best_by(&[0.0, 1.0]);
        assert!(best1.obj[1] < 0.1, "min f1 {:?}", best1.obj);
    }

    #[test]
    fn hard_limit_respected() {
        let p = Toy;
        let cfg = AmosaConfig { soft_limit: 5, hard_limit: 8, ..Default::default() };
        let mut a = Amosa::new(&p, cfg);
        a.run();
        assert!(a.archive.len() <= 8);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = Toy;
        let run = |seed| {
            let mut a = Amosa::new(
                &p,
                AmosaConfig { seed, iters_per_temp: 20, ..Default::default() },
            );
            a.run();
            a.archive.iter().map(|m| m.sol).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
    }
}

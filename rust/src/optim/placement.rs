//! CPU/MC tile placement on the mesh (§5.2, following [49]): jointly
//! minimize CPU-MC hop distance (CPU latency QoS) and the traffic-weighted
//! hop count of the many-to-few GPU-MC traffic (throughput QoS).
//!
//! Solutions permute the tile-kind vector; perturbation swaps a CPU or MC
//! tile with a random other tile. On a mesh, hop counts are Manhattan, so
//! objectives are closed-form — no BFS needed.

use crate::model::{SystemConfig, TileKind};
use crate::optim::amosa::{Amosa, AmosaConfig, Problem, SearchObserver};
use crate::util::rng::Rng;

pub struct MeshPlacement<'a> {
    pub sys: &'a SystemConfig,
    /// Relative MC->GPU traffic weight (reply-heavy asymmetry).
    pub gpu_weight: f64,
    /// Relative CPU<->MC traffic weight.
    pub cpu_weight: f64,
}

impl<'a> MeshPlacement<'a> {
    fn objective_pair(&self, tiles: &[TileKind]) -> (f64, f64) {
        let w = self.sys.width;
        let hop = |a: usize, b: usize| {
            ((a / w).abs_diff(b / w) + (a % w).abs_diff(b % w)) as f64
        };
        let mut cpus = Vec::new();
        let mut mcs = Vec::new();
        let mut gpus = Vec::new();
        for (i, t) in tiles.iter().enumerate() {
            match t {
                TileKind::Cpu => cpus.push(i),
                TileKind::Mc => mcs.push(i),
                TileKind::Gpu => gpus.push(i),
            }
        }
        // CPU QoS: mean CPU-MC hop distance.
        let mut cpu_mc = 0.0;
        for &c in &cpus {
            for &m in &mcs {
                cpu_mc += hop(c, m);
            }
        }
        cpu_mc /= (cpus.len() * mcs.len()).max(1) as f64;
        // Throughput proxy: traffic-weighted GPU<->MC hop count.
        let mut twhc = 0.0;
        for &g in &gpus {
            for &m in &mcs {
                twhc += self.gpu_weight * hop(g, m);
            }
        }
        twhc /= (gpus.len() * mcs.len()).max(1) as f64;
        (self.cpu_weight * cpu_mc, twhc)
    }
}

impl<'a> Problem for MeshPlacement<'a> {
    type Sol = Vec<TileKind>;

    fn num_objectives(&self) -> usize {
        2
    }

    fn objectives_into(&self, tiles: &Self::Sol, out: &mut [f64]) {
        let (a, b) = self.objective_pair(tiles);
        out[0] = a;
        out[1] = b;
    }

    fn perturb(&self, tiles: &Self::Sol, rng: &mut Rng) -> Self::Sol {
        let mut t = tiles.clone();
        // swap a non-GPU tile with any other tile
        let special: Vec<usize> = (0..t.len())
            .filter(|&i| t[i] != TileKind::Gpu)
            .collect();
        let a = *rng.pick(&special);
        let b = rng.below(t.len());
        t.swap(a, b);
        t
    }

    fn initial(&self, rng: &mut Rng) -> Self::Sol {
        let mut t = self.sys.tiles.clone();
        rng.shuffle(&mut t);
        t
    }
}

/// Optimize CPU/MC placement on the mesh; returns a `SystemConfig` with
/// the best (balanced-scalarization) placement.
pub fn optimize_placement(sys: &SystemConfig, seed: u64) -> SystemConfig {
    optimize_placement_observed(sys, seed, None)
}

/// [`optimize_placement`] with an optional read-only [`SearchObserver`]
/// (the "placement" stage of the design-search eval profiler). The
/// returned placement is byte-identical with or without one.
pub fn optimize_placement_observed(
    sys: &SystemConfig,
    seed: u64,
    obs: Option<&mut SearchObserver>,
) -> SystemConfig {
    let p = MeshPlacement { sys, gpu_weight: 1.0, cpu_weight: 1.0 };
    let cfg = AmosaConfig {
        initial_temp: 50.0,
        cooling: 0.85,
        iters_per_temp: 300,
        seed,
        ..Default::default()
    };
    let mut a = Amosa::new(&p, cfg);
    a.run_observed(obs);
    let best = a.best_by(&[1.0, 1.0]);
    sys.with_tiles(best.sol.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_clusters_mcs_and_cpus_centrally() {
        let sys = SystemConfig::paper_8x8();
        let placed = optimize_placement(&sys, 11);
        // composition preserved
        assert_eq!(placed.cpus().len(), 4);
        assert_eq!(placed.mcs().len(), 4);
        assert_eq!(placed.gpus().len(), 56);
        // optimized placement puts MCs well inside the die: mean MC->center
        // distance must beat the worst case (corners) comfortably.
        let center = 3.5;
        let mean_mc_center: f64 = placed
            .mcs()
            .iter()
            .map(|&m| {
                let (r, c) = ((m / 8) as f64, (m % 8) as f64);
                (r - center).abs() + (c - center).abs()
            })
            .sum::<f64>()
            / 4.0;
        assert!(mean_mc_center < 3.0, "MCs at mean center distance {mean_mc_center}");
        // CPU-MC mean hops should be small (clustered)
        let mut acc = 0.0;
        for &c in &placed.cpus() {
            for &m in &placed.mcs() {
                acc += placed.hop_dist(c, m) as f64;
            }
        }
        acc /= 16.0;
        assert!(acc <= 4.0, "CPU-MC mean hops {acc}");
    }

    #[test]
    fn objectives_reward_central_mcs() {
        let sys = SystemConfig::paper_8x8();
        let p = MeshPlacement { sys: &sys, gpu_weight: 1.0, cpu_weight: 1.0 };
        // corners-MC layout
        let mut corner = vec![TileKind::Gpu; 64];
        for i in [0usize, 7, 56, 63] {
            corner[i] = TileKind::Mc;
        }
        for i in [27usize, 28, 35, 36] {
            corner[i] = TileKind::Cpu;
        }
        let central = sys.tiles.clone();
        let oc = p.objectives(&corner);
        let oz = p.objectives(&central);
        assert!(oz[1] < oc[1], "central MCs should cut GPU twhc: {oz:?} vs {oc:?}");
    }

    #[test]
    fn perturb_preserves_composition() {
        let sys = SystemConfig::paper_8x8();
        let p = MeshPlacement { sys: &sys, gpu_weight: 1.0, cpu_weight: 1.0 };
        let mut rng = Rng::new(5);
        let mut t = p.initial(&mut rng);
        for _ in 0..100 {
            t = p.perturb(&t, &mut rng);
        }
        assert_eq!(t.iter().filter(|&&k| k == TileKind::Cpu).count(), 4);
        assert_eq!(t.iter().filter(|&&k| k == TileKind::Mc).count(), 4);
    }
}

//! Irregular wireline link placement — the constrained MOO of §4.2.1-4.2.2
//! (Eqns 6-9): choose `L` undirected links over `R` routers minimizing
//! (Ū, σ) under k_avg / k_max / connectivity constraints.
//!
//! Solutions are edge lists; perturbation rewires one random link to a new
//! feasible endpoint pair (preserving L, the port bounds, and
//! connectivity). Objective evaluation is the analytic Eqn 3-5 model in
//! `noc::analysis`, sharing scratch buffers across the ~10^5 AMOSA
//! evaluations.

use std::cell::RefCell;

use crate::model::SystemConfig;
use crate::noc::analysis::{analyze_objectives, AnalysisScratch, TrafficMatrix};
use crate::noc::topology::Topology;
use crate::optim::amosa::Problem;
use crate::util::rng::Rng;

/// A candidate wireline connectivity: exactly `L` undirected edges.
pub type LinkSolution = Vec<(usize, usize)>;

pub struct LinkPlacement<'a> {
    pub sys: &'a SystemConfig,
    pub traffic: &'a TrafficMatrix,
    /// Link budget L — fixed to the mesh's link count (no area overhead).
    pub num_links: usize,
    /// Maximum router port count (Eqn 8); swept 4..=7 in §5.3.1.
    pub k_max: usize,
    /// Average router port count bound (Eqn 7).
    pub k_avg: f64,
    /// Maximum wireline link length (mm). The WiHetNoC design restricts
    /// wireline links to short/medium reach — long-range connectivity is
    /// the wireless overlay's job (§4.2.3: "the longest links [are made]
    /// wireless"). `None` = unrestricted (the HetNoC ablation, where long
    /// pipelined metal wires stand in for the wireless links).
    pub max_link_mm: Option<f64>,
    scratch: RefCell<AnalysisScratch>,
}

impl<'a> LinkPlacement<'a> {
    pub fn new(
        sys: &'a SystemConfig,
        traffic: &'a TrafficMatrix,
        num_links: usize,
        k_max: usize,
    ) -> Self {
        let n = sys.num_tiles();
        LinkPlacement {
            sys,
            traffic,
            num_links,
            k_max,
            k_avg: 4.0,
            max_link_mm: None,
            scratch: RefCell::new(AnalysisScratch::new(n)),
        }
    }

    pub fn with_max_link_mm(mut self, mm: Option<f64>) -> Self {
        self.max_link_mm = mm;
        self
    }

    pub fn build_topology(&self, sol: &LinkSolution) -> Topology {
        Topology::from_edges(self.sys, sol)
    }

    /// Feasibility: L links, degree bounds, connected (Eqns 7-9).
    pub fn is_feasible(&self, sol: &LinkSolution) -> bool {
        if sol.len() != self.num_links {
            return false;
        }
        let t = self.build_topology(sol);
        t.k_max() <= self.k_max && t.k_avg() <= self.k_avg + 1e-9 && t.is_connected()
    }
}

impl<'a> Problem for LinkPlacement<'a> {
    type Sol = LinkSolution;

    fn num_objectives(&self) -> usize {
        2
    }

    /// (Ū, σ) of Eqns 4-5. Infeasible (disconnected) solutions are fenced
    /// with +inf so AMOSA never archives them.
    fn objectives_into(&self, sol: &Self::Sol, out: &mut [f64]) {
        let topo = self.build_topology(sol);
        let mut scratch = self.scratch.borrow_mut();
        let a = analyze_objectives(&topo, self.traffic, &mut scratch);
        if !a.connected {
            out[0] = f64::INFINITY;
            out[1] = f64::INFINITY;
        } else {
            out[0] = a.u_mean;
            out[1] = a.u_std;
        }
    }

    /// Rewire one random link, keeping all constraints; falls back to the
    /// unmodified solution if no feasible rewire is found in a few tries.
    ///
    /// Hot path (§Perf): the topology is built once and mutated in place —
    /// remove a victim, trial-add endpoints, connectivity-check, restore
    /// on failure — instead of rebuilding the graph per attempt.
    fn perturb(&self, sol: &Self::Sol, rng: &mut Rng) -> Self::Sol {
        let n = self.sys.num_tiles();
        let mut topo = Topology::from_edges(self.sys, sol);
        for _ in 0..16 {
            let victim = rng.below(topo.links.len());
            let (va, vb) = (topo.links[victim].a, topo.links[victim].b);
            topo.remove_link(victim);
            for _ in 0..64 {
                let a = rng.below(n);
                let b = rng.below(n);
                if a == b || topo.has_link(a, b) {
                    continue;
                }
                if topo.degree(a) + 1 > self.k_max || topo.degree(b) + 1 > self.k_max {
                    continue;
                }
                if let Some(mm) = self.max_link_mm {
                    if self.sys.dist_mm(a, b) > mm {
                        continue;
                    }
                }
                let id = topo.add_link_with_geometry(self.sys, a, b);
                if topo.is_connected() {
                    return topo.edges();
                }
                topo.remove_link(id);
            }
            // no feasible replacement for this victim: restore and retry
            topo.add_link_with_geometry(self.sys, va, vb);
        }
        sol.clone()
    }

    /// Start from the mesh (feasible by construction) with a few random
    /// rewires for archive diversity.
    fn initial(&self, rng: &mut Rng) -> Self::Sol {
        let mesh = Topology::mesh(self.sys);
        let mut sol: LinkSolution = mesh.edges();
        debug_assert_eq!(sol.len(), self.num_links);
        for _ in 0..8 {
            sol = self.perturb(&sol, rng);
        }
        sol
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::amosa::{Amosa, AmosaConfig};

    fn uniform_many_to_few(sys: &SystemConfig) -> TrafficMatrix {
        let mut e = Vec::new();
        for &g in &sys.gpus() {
            for &m in &sys.mcs() {
                e.push((g as u32, m as u32, 0.01));
                e.push((m as u32, g as u32, 0.03));
            }
        }
        TrafficMatrix::from_entries(sys.num_tiles(), e)
    }

    #[test]
    fn mesh_start_is_feasible() {
        let sys = SystemConfig::paper_8x8();
        let tm = uniform_many_to_few(&sys);
        let p = LinkPlacement::new(&sys, &tm, 112, 4);
        let mesh: LinkSolution = Topology::mesh(&sys).edges();
        assert!(p.is_feasible(&mesh));
    }

    #[test]
    fn perturb_preserves_feasibility() {
        let sys = SystemConfig::small_4x4();
        let tm = uniform_many_to_few(&sys);
        let p = LinkPlacement::new(&sys, &tm, 24, 5);
        let mut rng = Rng::new(1);
        let mut sol = p.initial(&mut rng);
        for _ in 0..50 {
            sol = p.perturb(&sol, &mut rng);
            assert!(p.is_feasible(&sol));
        }
    }

    #[test]
    fn optimizer_beats_mesh_on_many_to_few() {
        let sys = SystemConfig::small_4x4();
        let tm = uniform_many_to_few(&sys);
        let p = LinkPlacement::new(&sys, &tm, 24, 6);
        let mesh_obj = p.objectives(&Topology::mesh(&sys).edges());
        let cfg = AmosaConfig {
            initial_temp: 50.0,
            cooling: 0.8,
            iters_per_temp: 120,
            seed: 3,
            ..Default::default()
        };
        let mut a = Amosa::new(&p, cfg);
        a.run();
        let best = a.best_by(&[1.0, 1.0]);
        // optimized irregular connectivity must improve mean utilization
        assert!(
            best.obj[0] < mesh_obj[0],
            "U: opt {} vs mesh {}",
            best.obj[0],
            mesh_obj[0]
        );
    }

    #[test]
    fn infeasible_fenced() {
        let sys = SystemConfig::small_4x4();
        let tm = uniform_many_to_few(&sys);
        let p = LinkPlacement::new(&sys, &tm, 24, 5);
        // two disconnected cliques-ish: all edges among 0..8 only
        let mut sol = Vec::new();
        'outer: for a in 0..8usize {
            for b in (a + 1)..8 {
                sol.push((a, b));
                if sol.len() == 24 {
                    break 'outer;
                }
            }
        }
        let obj = p.objectives(&sol);
        assert!(obj[0].is_infinite());
    }
}

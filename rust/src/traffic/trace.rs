//! Concrete message traces for the simulator, generated from
//! `LayerPhase` volumes.
//!
//! Reads become 1-flit `ReadReq` messages (the simulator spawns the
//! cache-line reply), writes become line-sized `WriteData` messages.
//! Arrivals are Bernoulli-per-cycle thinned to the phase's rate; each GPU
//! tile is active in staggered bursts (the Fig 7 temporal-locality
//! wavefront), and addresses interleave across the MCs.

use crate::model::SystemConfig;
use crate::noc::sim::{Message, MsgClass};
use crate::traffic::phases::LayerPhase;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Downsampling: keep this fraction of the phase's messages (and
    /// duration) so experiment sweeps stay fast. 1.0 = full phase.
    pub scale: f64,
    /// Fraction of the phase during which a given GPU tile is actively
    /// issuing (burst duty cycle; bursts are staggered round-robin).
    pub burst_duty: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { scale: 1.0, burst_duty: 0.5, seed: 0x7ACE }
    }
}

impl TraceConfig {
    /// The trace window a phase of `duration_cycles` occupies under this
    /// config's downsampling (floored at 16 cycles so even tiny phases
    /// get a schedulable window). Exposed so schedule references compare
    /// against exactly what [`phase_trace`] generates.
    pub fn window(&self, duration_cycles: u64) -> u64 {
        ((duration_cycles as f64 * self.scale).ceil() as u64).max(16)
    }
}

/// Generate the message trace for one phase, starting at `start_cycle`.
/// Returns (messages, phase duration in cycles).
pub fn phase_trace(
    sys: &SystemConfig,
    phase: &LayerPhase,
    start_cycle: u64,
    cfg: &TraceConfig,
    rng: &mut Rng,
) -> (Vec<Message>, u64) {
    let dur = cfg.window(phase.duration_cycles);
    let line = sys.line_bytes;
    let line_flits = sys.line_bytes / sys.flit_bytes + 1;
    let all_gpus = sys.gpus();
    // mapping-restricted phases inject only from their assigned GPU tiles
    let gpus: &[usize] =
        if phase.gpu_tiles.is_empty() { &all_gpus } else { &phase.gpu_tiles };
    let cpus = sys.cpus();
    let mcs = sys.mcs();
    let mut out = Vec::new();

    let emit_cohort = |tiles: &[usize],
                           reads: u64,
                           writes: u64,
                           bursty: bool,
                           rng: &mut Rng,
                           out: &mut Vec<Message>| {
        let reads = (reads as f64 * cfg.scale).round() as u64;
        let writes = (writes as f64 * cfg.scale).round() as u64;
        for i in 0..reads {
            let src_idx = (i as usize) % tiles.len();
            let src = tiles[src_idx];
            let dst = mcs[rng.below(mcs.len())];
            let t = if bursty {
                burst_time(dur, tiles.len(), src_idx, cfg.burst_duty, rng)
            } else {
                rng.below(dur as usize) as u64
            };
            out.push(Message { src, dst, flits: 1, class: MsgClass::ReadReq, inject_at: start_cycle + t });
        }
        // write-allocate: each written line is an RFO fill (ReadReq ->
        // line reply) followed by the dirty-line writeback (WriteData ->
        // ack) a little later.
        for i in 0..writes {
            let src_idx = (i as usize) % tiles.len();
            let src = tiles[src_idx];
            let dst = mcs[rng.below(mcs.len())];
            let t = if bursty {
                burst_time(dur, tiles.len(), src_idx, cfg.burst_duty, rng)
            } else {
                rng.below(dur as usize) as u64
            };
            out.push(Message { src, dst, flits: 1, class: MsgClass::ReadReq, inject_at: start_cycle + t });
            let wb = t + 40 + rng.below(64) as u64; // dirty-eviction delay
            out.push(Message {
                src,
                dst,
                flits: line_flits,
                class: MsgClass::WriteData,
                inject_at: start_cycle + wb,
            });
        }
    };

    emit_cohort(
        gpus,
        phase.gpu_read_bytes.div_ceil(line),
        phase.gpu_write_bytes.div_ceil(line),
        true,
        rng,
        &mut out,
    );
    emit_cohort(
        &cpus,
        phase.cpu_read_bytes.div_ceil(line),
        phase.cpu_write_bytes.div_ceil(line),
        false,
        rng,
        &mut out,
    );

    // core-core control (CPU <-> GPU launch/coherence), 1-flit messages
    let cc = (phase.core_core_flits as f64 * cfg.scale).round() as u64;
    for i in 0..cc {
        let (src, dst) = if i % 2 == 0 {
            (cpus[rng.below(cpus.len())], gpus[rng.below(gpus.len())])
        } else {
            (gpus[rng.below(gpus.len())], cpus[rng.below(cpus.len())])
        };
        out.push(Message {
            src,
            dst,
            flits: 1,
            class: MsgClass::Control,
            inject_at: start_cycle + rng.below(dur as usize) as u64,
        });
    }

    out.sort_by_key(|m| m.inject_at);
    (out, dur)
}

/// Staggered burst schedule: tile `idx` of `n` is active during a window
/// of `duty * dur` cycles whose start rotates with the tile index.
fn burst_time(dur: u64, n: usize, idx: usize, duty: f64, rng: &mut Rng) -> u64 {
    let window = ((dur as f64 * duty) as u64).max(1);
    let offset = (dur - window) as f64 * (idx as f64 / n.max(1) as f64);
    offset as u64 + rng.below(window as usize) as u64
}

/// Full-iteration trace: phases executed back-to-back. Returns the trace
/// plus per-phase (start, end) windows (used by the per-layer experiments).
pub fn training_trace(
    sys: &SystemConfig,
    phases: &[LayerPhase],
    cfg: &TraceConfig,
) -> (Vec<Message>, Vec<(u64, u64)>) {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0u64;
    let mut all = Vec::new();
    let mut windows = Vec::new();
    for p in phases {
        let (mut msgs, dur) = phase_trace(sys, p, t, cfg, &mut rng);
        all.append(&mut msgs);
        windows.push((t, t + dur));
        t += dur;
    }
    all.sort_by_key(|m| m.inject_at);
    (all, windows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TileKind;
    use crate::model::lenet;
    use crate::traffic::phases::model_phases;

    fn phase_fixture() -> (SystemConfig, Vec<LayerPhase>) {
        let sys = SystemConfig::paper_8x8();
        let tm = model_phases(&sys, &lenet(), 8);
        (sys, tm.phases)
    }

    #[test]
    fn trace_counts_match_volumes() {
        let (sys, phases) = phase_fixture();
        let p = &phases[0]; // C1 forward
        let cfg = TraceConfig { scale: 1.0, ..Default::default() };
        let mut rng = Rng::new(1);
        let (msgs, dur) = phase_trace(&sys, p, 0, &cfg, &mut rng);
        let reads = msgs.iter().filter(|m| m.class == MsgClass::ReadReq).count() as u64;
        let writes = msgs.iter().filter(|m| m.class == MsgClass::WriteData).count() as u64;
        let read_lines = (p.gpu_read_bytes.div_ceil(sys.line_bytes))
            + p.cpu_read_bytes.div_ceil(sys.line_bytes);
        let write_lines = (p.gpu_write_bytes.div_ceil(sys.line_bytes))
            + p.cpu_write_bytes.div_ceil(sys.line_bytes);
        // write-allocate: each write line adds an RFO read request
        assert_eq!(reads, read_lines + write_lines);
        assert_eq!(writes, write_lines);
        assert!(dur >= p.duration_cycles);
        // all sources are GPU or CPU tiles, all dsts MCs (except control)
        for m in &msgs {
            if m.class != MsgClass::Control {
                assert_ne!(sys.tiles[m.src], TileKind::Mc);
                assert_eq!(sys.tiles[m.dst], TileKind::Mc);
            }
            if m.class != MsgClass::WriteData {
                assert!(m.inject_at < dur);
            }
        }
    }

    #[test]
    fn scaling_reduces_messages_proportionally() {
        let (sys, phases) = phase_fixture();
        let p = &phases[0];
        let mut rng = Rng::new(2);
        let full = phase_trace(&sys, p, 0, &TraceConfig::default(), &mut rng).0.len();
        let mut rng = Rng::new(2);
        let half = phase_trace(
            &sys,
            p,
            0,
            &TraceConfig { scale: 0.5, ..Default::default() },
            &mut rng,
        )
        .0
        .len();
        let ratio = half as f64 / full as f64;
        assert!((0.4..=0.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn training_trace_phases_sequential() {
        let (sys, phases) = phase_fixture();
        let cfg = TraceConfig { scale: 0.02, ..Default::default() };
        let (msgs, windows) = training_trace(&sys, &phases, &cfg);
        assert_eq!(windows.len(), phases.len());
        for w in windows.windows(2) {
            assert_eq!(w[0].1, w[1].0, "windows must abut");
        }
        assert!(!msgs.is_empty());
        // sorted by time
        for m in msgs.windows(2) {
            assert!(m[0].inject_at <= m[1].inject_at);
        }
    }

    #[test]
    fn bursts_stagger_gpu_activity() {
        let (sys, phases) = phase_fixture();
        let p = &phases[0];
        let cfg = TraceConfig { scale: 0.25, burst_duty: 0.3, seed: 5 };
        let mut rng = Rng::new(5);
        let (msgs, dur) = phase_trace(&sys, p, 0, &cfg, &mut rng);
        // first GPU tile's messages must concentrate early, last tile's late
        let gpus = sys.gpus();
        let mean_t = |tile: usize| -> f64 {
            let v: Vec<f64> = msgs
                .iter()
                .filter(|m| m.src == tile)
                .map(|m| m.inject_at as f64)
                .collect();
            v.iter().sum::<f64>() / v.len().max(1) as f64
        };
        let first = mean_t(gpus[0]);
        let last = mean_t(*gpus.last().unwrap());
        assert!(last > first, "stagger: first {first} last {last} dur {dur}");
    }

    #[test]
    fn deterministic_with_seed() {
        let (sys, phases) = phase_fixture();
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let (a, _) = training_trace(&sys, &phases, &cfg);
        let (b, _) = training_trace(&sys, &phases, &cfg);
        assert_eq!(a.len(), b.len());
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.src == y.src && x.dst == y.dst && x.inject_at == y.inject_at));
    }
}

//! CNN-training traffic model: per-layer message volumes, frequency
//! matrices (f_ij), and concrete simulator traces (§5.1 of the paper).

pub mod phases;
pub mod trace;

pub use phases::{model_phases, LayerPhase, TrafficModel};
pub use trace::{phase_trace, training_trace, TraceConfig};

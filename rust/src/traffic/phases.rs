//! Per-layer traffic phases: how one training iteration of a CNN maps to
//! on-chip messages on the heterogeneous platform (paper §5.1).
//!
//! Volume accounting (first-principles, per layer and pass):
//!   forward : GPUs read the layer input + weights from the MCs (L2/DRAM),
//!             write the layer output back;
//!   backward: GPUs read the output gradient, saved input, and weights;
//!             write the input gradient and the weight gradient;
//!             CPUs then read (gradient, weights) and write updated weights
//!             (the SGD step), plus per-layer kernel-launch control.
//! Fully-connected layers run on the CPUs (the paper observes FC traffic
//! is CPU<->MC dominated).
//!
//! Duration model: a layer occupies
//!   `max(compute_cycles, bytes / mc_bandwidth) * stall_factor(kind)`
//! where `stall_factor` captures the occupancy/latency losses gem5-gpu
//! measures implicitly (short latency-bound pooling kernels achieve a
//! small fraction of peak bandwidth). The stall factors are the only
//! calibrated constants in the model — everything else is derived —
//! and they are what makes conv inject hardest, then pooling, then FC
//! (the Fig 5 ordering). See DESIGN.md §2.

use crate::model::cnn::{LayerKind, ModelSpec, Pass};
use crate::model::SystemConfig;
use crate::noc::analysis::TrafficMatrix;

/// Latency/occupancy stall factor per layer kind (dimensionless >= 1).
pub fn stall_factor(kind: LayerKind) -> f64 {
    match kind {
        LayerKind::Conv => 1.0,
        LayerKind::MaxPool | LayerKind::AvgPool => 6.0,
        LayerKind::Lrn => 4.0,
        // FC layers run on the CPUs: tiny GEMM + softmax/loss + global
        // sync; launch and serialization overheads dominate.
        LayerKind::Dense => 25.0,
    }
}

/// CPU MAC throughput per core per CPU clock (SIMD FMA abstracted).
pub const CPU_MACS_PER_CYCLE: u64 = 16;

/// Directory/coherence control overhead: extra core<->core flits per
/// transferred cache line (MESI forwards/invalidations). Calibrated so
/// many-to-few traffic lands near the paper's 93% (LeNet) / 89% (CDBNet).
pub const COHERENCE_FLITS_PER_LINE: f64 = 0.35;

/// Fraction of a GPU layer's MC volume that the CPUs also move while
/// orchestrating it (framework loop: completion flags, descriptor reads,
/// next-layer weight prefetch). This is what exposes CPU packets to the
/// GPU-congested windows — the contention the dedicated wireless channel
/// exists to bypass (Fig 7 / §5.1).
/// (Sized so the CPU-MC flow fits comfortably in one 16 Gbps channel.)
pub const CPU_ORCHESTRATION_FRACTION: f64 = 0.005;

/// One layer x pass worth of traffic and timing.
#[derive(Debug, Clone)]
pub struct LayerPhase {
    pub layer: String,
    pub kind: LayerKind,
    pub pass: Pass,
    /// Display tag, e.g. "C1", "P2", "F1" — the paper's x-axis labels.
    pub tag: String,
    /// Bytes GPUs read from MCs / write to MCs during this phase.
    pub gpu_read_bytes: u64,
    pub gpu_write_bytes: u64,
    /// Bytes CPUs read from / write to MCs.
    pub cpu_read_bytes: u64,
    pub cpu_write_bytes: u64,
    /// Core<->core control/coherence flits (CPU<->GPU).
    pub core_core_flits: u64,
    /// Phase duration in NoC cycles (zero-contention execution model).
    pub duration_cycles: u64,
    /// GPU tiles that compute (and inject) during this phase. Empty means
    /// *all* GPU tiles of the system — the legacy behaviour and the
    /// data-parallel mapping; the layer-pipelined mapping restricts each
    /// phase to its stage's tile slice.
    pub gpu_tiles: Vec<usize>,
}

impl LayerPhase {
    fn lines(bytes: u64, line: u64) -> u64 {
        bytes.div_ceil(line)
    }

    /// Flits injected by cores toward MCs. Caches are write-allocate:
    /// a read is a 1-flit request; a write is a 1-flit RFO request plus a
    /// line-sized writeback.
    pub fn core_to_mc_flits(&self, sys: &SystemConfig) -> u64 {
        let line_flits = sys.line_bytes / sys.flit_bytes + 1;
        let reads = Self::lines(self.gpu_read_bytes + self.cpu_read_bytes, sys.line_bytes);
        let writes = Self::lines(self.gpu_write_bytes + self.cpu_write_bytes, sys.line_bytes);
        reads + writes * (1 + line_flits)
    }

    /// Reply flits from MCs: line reply per read, line fill (RFO) + 1-flit
    /// writeback ack per write. Reads being reply-heavy is what makes
    /// MC-to-core traffic exceed core-to-MC (Fig 6).
    pub fn mc_to_core_flits(&self, sys: &SystemConfig) -> u64 {
        let line_flits = sys.line_bytes / sys.flit_bytes + 1;
        let reads = Self::lines(self.gpu_read_bytes + self.cpu_read_bytes, sys.line_bytes);
        let writes = Self::lines(self.gpu_write_bytes + self.cpu_write_bytes, sys.line_bytes);
        reads * line_flits + writes * (line_flits + 1)
    }

    pub fn total_flits(&self, sys: &SystemConfig) -> u64 {
        self.core_to_mc_flits(sys) + self.mc_to_core_flits(sys) + self.core_core_flits
    }

    /// Flits per cycle — the Fig 5 quantity.
    pub fn injection_rate(&self, sys: &SystemConfig) -> f64 {
        self.total_flits(sys) as f64 / self.duration_cycles.max(1) as f64
    }

    /// MC-to-core over core-to-MC ratio — the Fig 6/16 asymmetry.
    pub fn asymmetry(&self, sys: &SystemConfig) -> f64 {
        self.mc_to_core_flits(sys) as f64 / self.core_to_mc_flits(sys).max(1) as f64
    }
}

/// Whole-iteration traffic model for one CNN.
#[derive(Debug, Clone)]
pub struct TrafficModel {
    pub model: String,
    pub batch: usize,
    pub phases: Vec<LayerPhase>,
}

/// Build the per-layer forward+backward phase list for `spec`.
///
/// This is the identity-mapping path: every GPU tile participates in
/// every phase. The workload subsystem (`crate::workload::lower`) builds
/// the same phases through [`layer_volumes`]/[`finish_phase`] and
/// adjusts the volumes for non-trivial mappings and skip connections.
pub fn model_phases(sys: &SystemConfig, spec: &ModelSpec, batch: usize) -> TrafficModel {
    let mut phases = Vec::new();
    for l in &spec.layers {
        let v = layer_volumes(l, batch, Pass::Forward);
        phases.push(finish_phase(
            sys,
            l,
            Pass::Forward,
            v,
            ExtraVolumes::default(),
            1.0,
            Vec::new(),
        ));
    }
    for l in spec.layers.iter().rev() {
        let v = layer_volumes(l, batch, Pass::Backward);
        phases.push(finish_phase(
            sys,
            l,
            Pass::Backward,
            v,
            ExtraVolumes::default(),
            1.0,
            Vec::new(),
        ));
    }
    TrafficModel { model: spec.name.clone(), batch, phases }
}

/// Mapping-induced extra bytes (replica weight traffic, skip-connection
/// reads). Applied *after* the CPU orchestration overhead — extra weight
/// fetches and residual adds reuse the kernels already launched, so they
/// add data volume, not descriptor traffic. Keeping them separate is what
/// makes the conservation invariants exact: `data:R` adds precisely
/// `(R-1) * 4 * weight_bytes` per weighted GPU layer, nothing more.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExtraVolumes {
    pub gpu_read: u64,
    pub gpu_write: u64,
    pub cpu_read: u64,
    pub cpu_write: u64,
}

/// Raw per-layer byte volumes and MAC count for one pass, before CPU
/// orchestration overheads and before any mapping adjustment. The
/// lowering pass derives [`ExtraVolumes`] (replica weight traffic,
/// skip-connection reads) and hands both to [`finish_phase`].
#[derive(Debug, Clone, Copy)]
pub struct LayerVolumes {
    pub gpu_read: u64,
    pub gpu_write: u64,
    pub cpu_read: u64,
    pub cpu_write: u64,
    pub macs: u64,
    /// Dense layers run on the CPUs (§5.1).
    pub on_cpu: bool,
}

/// First-principles volume accounting for one layer x pass (the doc
/// comment at the top of this module).
pub fn layer_volumes(l: &crate::model::cnn::Layer, batch: usize, pass: Pass) -> LayerVolumes {
    let on_cpu = l.kind == LayerKind::Dense;
    let (mut gr, mut gw, mut cr, mut cw) = (0u64, 0u64, 0u64, 0u64);
    match pass {
        Pass::Forward => {
            let r = l.in_bytes(batch) + l.weight_bytes();
            let w = l.out_bytes(batch);
            if on_cpu {
                cr += r;
                cw += w;
            } else {
                gr += r;
                gw += w;
            }
        }
        Pass::Backward => {
            // read: dY, saved X, W; write: dX, dW
            let r = l.out_bytes(batch) + l.in_bytes(batch) + l.weight_bytes();
            let w = l.in_bytes(batch) + l.weight_bytes();
            if on_cpu {
                cr += r;
                cw += w;
            } else {
                gr += r;
                gw += w;
            }
            // SGD update on CPUs for weighted layers: read (W, dW), write W'
            if l.has_params() {
                cr += 2 * l.weight_bytes();
                cw += l.weight_bytes();
            }
        }
    }
    let macs = match pass {
        Pass::Forward => l.macs(batch),
        Pass::Backward => l.bwd_macs(batch),
    };
    LayerVolumes { gpu_read: gr, gpu_write: gw, cpu_read: cr, cpu_write: cw, macs, on_cpu }
}

/// Turn [`LayerVolumes`] (+[`ExtraVolumes`]) into a [`LayerPhase`]: CPU
/// orchestration overheads, launch/coherence control flits, and the
/// duration model.
///
/// `gpu_share` is the fraction of the chip's aggregate GPU throughput
/// computing this phase (1.0 = all GPU tiles; a pipeline stage passes its
/// tile fraction). `gpu_tiles` restricts the injecting tiles (empty =
/// all). With zero extras, `gpu_share = 1.0`, and empty `gpu_tiles` this
/// reproduces the legacy phase byte-for-byte.
pub fn finish_phase(
    sys: &SystemConfig,
    l: &crate::model::cnn::Layer,
    pass: Pass,
    v: LayerVolumes,
    extra: ExtraVolumes,
    gpu_share: f64,
    gpu_tiles: Vec<usize>,
) -> LayerPhase {
    let LayerVolumes {
        gpu_read: mut gr,
        gpu_write: mut gw,
        cpu_read: mut cr,
        cpu_write: mut cw,
        macs,
        on_cpu,
    } = v;
    // CPU orchestration of GPU layers: flags/descriptors/prefetch
    if !on_cpu {
        cr += ((gr + gw) as f64 * CPU_ORCHESTRATION_FRACTION) as u64;
        cw += (gw as f64 * CPU_ORCHESTRATION_FRACTION * 0.25) as u64;
    }
    gr += extra.gpu_read;
    gw += extra.gpu_write;
    cr += extra.cpu_read;
    cw += extra.cpu_write;
    // per-layer kernel-launch control: CPU -> each participating GPU tile
    // and back
    let n_gpu = if gpu_tiles.is_empty() {
        sys.gpus().len() as u64
    } else {
        gpu_tiles.len() as u64
    };
    let launch_flits = if on_cpu { 0 } else { 4 * n_gpu };
    let lines = (gr + gw + cr + cw).div_ceil(sys.line_bytes);
    let core_core = launch_flits + (lines as f64 * COHERENCE_FLITS_PER_LINE) as u64;

    // duration: compute- or bandwidth-limited, x stall factor
    let compute_cycles = if on_cpu {
        let cpu_macs_per_sec = sys.cpus().len() as f64 * CPU_MACS_PER_CYCLE as f64 * sys.cpu_clock_hz;
        (macs as f64 / cpu_macs_per_sec * sys.noc_clock_hz).ceil() as u64
    } else {
        (macs as f64 / (sys.gpu_total_macs_per_sec() * gpu_share) * sys.noc_clock_hz).ceil() as u64
    };
    let mc_bw_bytes_per_cycle = sys.mcs().len() as f64 * sys.mc_bw_bytes_per_cycle;
    let mem_cycles = ((gr + gw + cr + cw) as f64 / mc_bw_bytes_per_cycle).ceil() as u64;
    let duration =
        ((compute_cycles.max(mem_cycles)) as f64 * stall_factor(l.kind)).ceil() as u64;

    LayerPhase {
        layer: l.name.clone(),
        kind: l.kind,
        pass,
        tag: l.name.clone(),
        gpu_read_bytes: gr,
        gpu_write_bytes: gw,
        cpu_read_bytes: cr,
        cpu_write_bytes: cw,
        core_core_flits: core_core,
        duration_cycles: duration.max(1),
        gpu_tiles,
    }
}

impl TrafficModel {
    pub fn total_cycles(&self) -> u64 {
        self.phases.iter().map(|p| p.duration_cycles).sum()
    }

    /// Fraction of all flits that are core<->MC (the paper's many-to-few
    /// share: 93% LeNet / 89% CDBNet).
    pub fn many_to_few_fraction(&self, sys: &SystemConfig) -> f64 {
        let mut m2f = 0u64;
        let mut total = 0u64;
        for p in &self.phases {
            let t = p.total_flits(sys);
            total += t;
            m2f += t - p.core_core_flits;
        }
        m2f as f64 / total.max(1) as f64
    }

    /// Total bytes moved between cores and MCs over the iteration (GPU +
    /// CPU reads and writes). The conservation invariant the workload
    /// lowering tests pin down: mappings redistribute this total, they
    /// never create or lose bytes.
    pub fn total_bytes(&self) -> u64 {
        self.phases
            .iter()
            .map(|p| {
                p.gpu_read_bytes + p.gpu_write_bytes + p.cpu_read_bytes + p.cpu_write_bytes
            })
            .sum()
    }

    /// Aggregate f_ij matrix (flits/cycle) over the whole iteration —
    /// the input to the Eqn 6 optimization.
    ///
    /// GPU traffic is spread uniformly over the phase's participating GPU
    /// tiles (all GPU tiles unless a mapping restricted the phase) and
    /// address-interleaved over MCs; CPU traffic over CPU tiles;
    /// core-core control flows CPU->GPU.
    pub fn fij(&self, sys: &SystemConfig) -> TrafficMatrix {
        let all_gpus = sys.gpus();
        let cpus = sys.cpus();
        let mcs = sys.mcs();
        let n = sys.num_tiles();
        let total_cycles = self.total_cycles().max(1) as f64;
        let line_flits = sys.line_bytes / sys.flit_bytes + 1;
        let mut acc = vec![0.0f64; n * n];
        for p in &self.phases {
            let gpus: &[usize] =
                if p.gpu_tiles.is_empty() { &all_gpus } else { &p.gpu_tiles };
            let g_reads = p.gpu_read_bytes.div_ceil(sys.line_bytes);
            let g_writes = p.gpu_write_bytes.div_ceil(sys.line_bytes);
            let c_reads = p.cpu_read_bytes.div_ceil(sys.line_bytes);
            let c_writes = p.cpu_write_bytes.div_ceil(sys.line_bytes);
            // flits in each direction (write-allocate: RFO + writeback)
            let g_to_mc = (g_reads + g_writes * (1 + line_flits)) as f64;
            let mc_to_g = (g_reads * line_flits + g_writes * (line_flits + 1)) as f64;
            let c_to_mc = (c_reads + c_writes * (1 + line_flits)) as f64;
            let mc_to_c = (c_reads * line_flits + c_writes * (line_flits + 1)) as f64;
            for &g in gpus {
                for &m in &mcs {
                    let share = 1.0 / (gpus.len() * mcs.len()) as f64;
                    acc[g * n + m] += g_to_mc * share;
                    acc[m * n + g] += mc_to_g * share;
                }
            }
            for &c in &cpus {
                for &m in &mcs {
                    let share = 1.0 / (cpus.len() * mcs.len()) as f64;
                    acc[c * n + m] += c_to_mc * share;
                    acc[m * n + c] += mc_to_c * share;
                }
            }
            let cc = p.core_core_flits as f64;
            for &c in &cpus {
                for &g in gpus {
                    let share = 0.5 / (cpus.len() * gpus.len()) as f64;
                    acc[c * n + g] += cc * share;
                    acc[g * n + c] += cc * share;
                }
            }
        }
        let entries = (0..n)
            .flat_map(|i| (0..n).map(move |j| (i, j)))
            .filter(|&(i, j)| acc[i * n + j] > 0.0)
            .map(|(i, j)| (i as u32, j as u32, acc[i * n + j] / total_cycles))
            .collect();
        TrafficMatrix::from_entries(n, entries)
    }

    /// Phases of one pass direction, in execution order.
    pub fn pass_phases(&self, pass: Pass) -> Vec<&LayerPhase> {
        self.phases.iter().filter(|p| p.pass == pass).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TileKind;
    use crate::model::{cdbnet, lenet};

    fn setup(model: fn() -> ModelSpec) -> (SystemConfig, TrafficModel) {
        let sys = SystemConfig::paper_8x8();
        let spec = model();
        let tm = model_phases(&sys, &spec, 32);
        (sys, tm)
    }

    #[test]
    fn phase_count_is_two_passes() {
        let (_, tm) = setup(lenet);
        assert_eq!(tm.phases.len(), 2 * lenet().layers.len());
        assert_eq!(tm.pass_phases(Pass::Forward).len(), lenet().layers.len());
    }

    #[test]
    fn fig5_ordering_conv_pool_fc() {
        for model in [lenet as fn() -> ModelSpec, cdbnet] {
            let (sys, tm) = setup(model);
            for pass in [Pass::Forward, Pass::Backward] {
                let inj = |kind: LayerKind| -> f64 {
                    let v: Vec<f64> = tm
                        .phases
                        .iter()
                        .filter(|p| p.pass == pass && p.kind == kind)
                        .map(|p| p.injection_rate(&sys))
                        .collect();
                    v.iter().sum::<f64>() / v.len().max(1) as f64
                };
                let (c, p, f) = (inj(LayerKind::Conv), inj(LayerKind::MaxPool), inj(LayerKind::Dense));
                assert!(c > p, "{model:?} {pass:?}: conv {c} <= pool {p}");
                assert!(p > f, "{model:?} {pass:?}: pool {p} <= fc {f}");
            }
        }
    }

    #[test]
    fn fig6_many_to_few_dominates() {
        let (sys, lenet_tm) = setup(lenet);
        let f = lenet_tm.many_to_few_fraction(&sys);
        assert!((0.85..=0.99).contains(&f), "LeNet many-to-few {f}");
        let (sys, cdb_tm) = setup(cdbnet);
        let f2 = cdb_tm.many_to_few_fraction(&sys);
        assert!((0.80..=0.99).contains(&f2), "CDBNet many-to-few {f2}");
    }

    #[test]
    fn fig6_reply_asymmetry() {
        let (sys, tm) = setup(lenet);
        // read-dominated conv layers must show MC->core > core->MC
        for p in &tm.phases {
            if p.kind == LayerKind::Conv {
                assert!(p.asymmetry(&sys) > 1.0, "{} {:?}", p.layer, p.pass);
            }
        }
    }

    #[test]
    fn fc_traffic_is_cpu_dominated() {
        let (_, tm) = setup(lenet);
        let f1 = tm
            .phases
            .iter()
            .find(|p| p.kind == LayerKind::Dense && p.pass == Pass::Forward)
            .unwrap();
        assert_eq!(f1.gpu_read_bytes + f1.gpu_write_bytes, 0);
        assert!(f1.cpu_read_bytes > 0);
    }

    #[test]
    fn backward_heavier_than_forward() {
        let (sys, tm) = setup(lenet);
        let sum = |pass: Pass| -> u64 {
            tm.phases
                .iter()
                .filter(|p| p.pass == pass)
                .map(|p| p.total_flits(&sys))
                .sum()
        };
        assert!(sum(Pass::Backward) > sum(Pass::Forward));
    }

    #[test]
    fn fij_is_many_to_few_shaped() {
        let (sys, tm) = setup(lenet);
        let fij = tm.fij(&sys);
        assert!(fij.total() > 0.0);
        let mcs = sys.mcs();
        // every entry touches an MC or is CPU<->GPU control
        for &(s, d, _) in &fij.entries {
            let touches_mc = mcs.contains(&(s as usize)) || mcs.contains(&(d as usize));
            let cc = sys.tiles[s as usize] != TileKind::Mc && sys.tiles[d as usize] != TileKind::Mc;
            assert!(touches_mc || cc);
        }
        // MC->GPU aggregate exceeds GPU->MC aggregate (reply asymmetry)
        let gpu_set: std::collections::HashSet<usize> = sys.gpus().into_iter().collect();
        let mut to_gpu = 0.0;
        let mut from_gpu = 0.0;
        for &(s, d, f) in &fij.entries {
            if mcs.contains(&(s as usize)) && gpu_set.contains(&(d as usize)) {
                to_gpu += f;
            }
            if gpu_set.contains(&(s as usize)) && mcs.contains(&(d as usize)) {
                from_gpu += f;
            }
        }
        assert!(to_gpu > from_gpu);
    }

    #[test]
    fn durations_positive_and_conv_longest() {
        let (_, tm) = setup(lenet);
        for p in &tm.phases {
            assert!(p.duration_cycles > 0, "{}", p.layer);
        }
    }
}
